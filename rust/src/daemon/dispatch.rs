//! The dispatch layer: daemon lifecycle plus the single dispatcher
//! thread that owns the FPGA (Cynq stack) and schedules requests
//! across users through the shared resource-elastic scheduler core
//! ([`crate::sched::SchedCore`]) — the same state machine the offline
//! simulator drives, so the live path gains variant selection,
//! multi-region spans, replication across free regions and
//! backlog-amortised reconfiguration avoidance (§4.4.3).
//!
//! Requests reach this module through the event-driven reactor
//! shard(s) in [`super::transport`] (non-blocking accept, epoll
//! readiness, per-shard slab connection tables), which decode frames
//! via [`super::session`] and forward [`Msg`](super::session) values
//! over the bounded dispatcher ingest channel.  Replies travel back
//! through a [`ReplySink`](super::transport::ReplySink), which either
//! answers a local in-process query channel or enqueues the value on
//! the originating connection's write buffer and wakes the shard that
//! owns it.  However many shards feed it, the dispatcher itself stays
//! single-threaded — decision sequences are unchanged by
//! construction.  The wire protocol itself is specified in
//! `rust/src/daemon/PROTOCOL.md`.
//!
//! The dispatcher keeps a *virtual clock*: each decision's service time
//! comes from the shared [`crate::sched::CostModel`] and completions
//! are replayed into the core in virtual-time order, exactly like the
//! simulator's event heap.  Reconfigurations are mirrored onto the
//! hardware at decision time; register programming + PJRT compute are
//! deferred to the decision's virtual completion, so a `Preempt`
//! decision can split a batch exactly where the virtual clock says —
//! the completed slice runs and is checkpointed
//! (`Cynq::checkpoint_accelerator`), the remainder resumes later
//! (`Cynq::restore_accelerator`), and no tile is computed twice.  For
//! one trace the simulator and the daemon produce identical decision
//! sequences — preemptions included — asserted by
//! `tests/sched_parity.rs`.
//!
//! ## Multi-fabric dispatch (the cluster layer)
//!
//! [`Daemon::start_cluster`] brings up one `Cynq` stack per board
//! (heterogeneous mixes welcome) behind one dispatcher thread driving
//! a [`crate::sched::ClusterCore`]: requests are routed to a board at
//! admission by a [`crate::sched::PlacementPolicy`]
//! (round-robin / least-loaded / locality), each board keeps its own
//! scheduler shard, resident-module map, snapshot store and preemption
//! tick, completions from every board replay through one virtual-time
//! heap, and an idle board steals queued work from an overloaded one
//! at the same round boundary the cluster simulator uses — so the
//! per-shard decision sequences still match the simulator verbatim
//! (`tests/cluster_parity.rs`).  The single-board constructors are a
//! one-board cluster.  `cluster-stats` / `board-stats` RPCs and the
//! per-board mirrors in [`DaemonStats::per_board`] expose the
//! per-board reconfiguration/preemption counters.  Device memory RPCs
//! (`alloc`/`write`/shm-import) are *broadcast* into every board's DDR
//! arena — the allocators evolve in lockstep, so a buffer has the same
//! physical address cluster-wide and a job can run on any board —
//! while reads come from the primary (board 0) arena, into which each
//! completed job's outputs are synced back (the explicit cross-board
//! result transfer).
//!
//! ## Isolation domains (the tenant security boundary)
//!
//! Every buffer belongs to exactly one tenant: allocations are tagged
//! with the tenant's arena owner id in each board's
//! [`crate::driver::DataManager`], clients name buffers by opaque
//! generational [`BufferHandle`]s (never physical addresses), and the
//! dispatcher resolves handles against the caller's tenant at the
//! `submit` trust boundary — a foreign or stale handle is refused with
//! a structured `denied`/`err` reply and the owning tenant's buffer is
//! untouched.  Compute runs under the decision's tenant
//! ([`Cynq::run_as`]), so DMA is bounds- and ownership-checked at the
//! driver too.  When a tenant's last connection departs, its whole
//! arena is reclaimed and all its handles are invalidated.  With
//! `--tenants` the daemon mints per-tenant bearer tokens at startup
//! and the `session` bind requires one (`register-tenant` mints more,
//! gated by the admin token).

use super::proto::{self, BufferHandle, Job};
use super::session::{
    busy_val, close_ticket, denied_val, err_val, fail_job, finish, ok, release_tenant, user_slot,
    Batch, BatchSink, MemOp, Msg, Ticket, MAX_OPEN_TICKETS,
};
use super::shm::SharedMem;
use super::transport::{Acceptor, Reactor, Waker, DEFAULT_MAX_CONNECTIONS, MAX_SHARDS};
use crate::accel::Catalog;
use crate::driver::{AccelSnapshot, Cynq, LoadedAccel, PhysAddr, TenantId};
use crate::json::{arr, i, obj, s, Value};
use crate::sched::{
    AdmissionConfig, AdmissionPipeline, AdmitRequest, ClusterCore, Decision, DecisionKind,
    FailDisposition, FaultPlan, MovedCkpt, OrderStrategy, PlacementKind, Policy, QosClass,
    Scenario, SymbolTable, Workload,
};
use crate::shell::ShellBoard;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::io;
use std::os::unix::net::UnixListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Daemon-side counters (Table 4/5 material). The scheduling counters
/// (`reconfig_loads`, `reuse_hits`, `skips`, `replications`) mirror the
/// core's [`crate::sched::SchedCounters`] — one source of truth for
/// both the simulator and the daemon.
#[derive(Debug, Default)]
pub struct DaemonStats {
    pub jobs: AtomicU64,
    pub reconfig_loads: AtomicU64,
    pub reuse_hits: AtomicU64,
    /// Rounds where a user was deferred (reconfiguration avoidance,
    /// busy fixed home).
    pub skips: AtomicU64,
    /// Reconfigurations that created an additional instance of an
    /// already-resident accelerator.
    pub replications: AtomicU64,
    /// Running requests checkpointed and requeued (time-domain
    /// preemption; mirrors `SchedCounters::preemptions`).
    pub preemptions: AtomicU64,
    /// Requeued remainders re-dispatched (mirrors
    /// `SchedCounters::resumes`).
    pub resumes: AtomicU64,
    /// Jobs served while ≥2 instances of their accelerator were
    /// resident (served by a replicated instance).
    pub replicated_jobs: AtomicU64,
    /// Scheduling decision time (pick user/region/variant), ns.
    pub sched_ns: AtomicU64,
    pub sched_decisions: AtomicU64,
    pub rpcs: AtomicU64,
    /// Requests handed to the scheduler by the admission pipeline's
    /// batched ingest.
    pub admitted: AtomicU64,
    /// Batches refused with a structured `Busy` reply (full admission
    /// queue or open-ticket cap).  Counts *batches*; the per-tenant
    /// `busy_rejected` in the stats RPC counts refused *requests*.
    pub busy_rejections: AtomicU64,
    /// Non-blocking `submit` batches (ticketed; `run` is submit+wait).
    pub async_submits: AtomicU64,
    /// Connections shed by the accept loop at the connection cap.
    pub connections_shed: AtomicU64,
    /// Requests routed to a board at admission (cluster layer).
    pub routed: AtomicU64,
    /// Requests moved between boards by work stealing.
    pub steals: AtomicU64,
    /// Boards failed over (drained + migrated) — the failure domain.
    pub failovers: AtomicU64,
    /// Requests migrated off failed boards with progress preserved.
    pub migrations: AtomicU64,
    /// Virtual ns of execution destroyed by faults.
    pub lost_ns: AtomicU64,
    /// Reconfiguration attempts that failed (injected or real
    /// `CynqError`s from `load_accelerator_at`).
    pub reconfig_failures: AtomicU64,
    /// Failed reconfigurations parked for a backoff retry.
    pub reconfig_retries: AtomicU64,
    /// Requests rejected at the reconfiguration retry cap.
    pub reconfig_rejections: AtomicU64,
    /// Dispatches re-queued after a transient run error.
    pub run_faults: AtomicU64,
    /// Per-board mirrors of each shard's scheduling counters — the
    /// cluster observability surface (`board-stats` reports from the
    /// same source).  Empty only for a `Default`-built block.
    pub per_board: Vec<BoardStats>,
}

/// Per-board mirror of one scheduler shard's
/// [`crate::sched::SchedCounters`].
#[derive(Debug, Default)]
pub struct BoardStats {
    /// Board name (`Ultra96`, `ZCU102`, ...).
    pub board: String,
    pub reconfigs: AtomicU64,
    pub reuses: AtomicU64,
    pub skips: AtomicU64,
    pub replications: AtomicU64,
    pub preemptions: AtomicU64,
    pub resumes: AtomicU64,
}

impl DaemonStats {
    /// A stats block sized for a cluster of `boards` (one per-board
    /// mirror each).
    pub fn for_boards(boards: &[ShellBoard]) -> DaemonStats {
        DaemonStats {
            per_board: boards
                .iter()
                .map(|b| BoardStats { board: b.name().to_string(), ..Default::default() })
                .collect(),
            ..Default::default()
        }
    }
}

/// Arena owner id of a daemon tenant.  Tenant ids start at 0 but owner
/// 0 is the kernel domain ([`crate::driver::KERNEL_OWNER`]), so daemon
/// tenants map to owners 1.. — the domains are disjoint by
/// construction and a tenant can never alias kernel-owned buffers.
fn owner_of(tenant: usize) -> TenantId {
    tenant as TenantId + 1
}

/// Tenant identity bookkeeping: named tenants (the `session` RPC)
/// share an id across connections; anonymous connections get a private
/// one, created lazily by the first RPC that needs a tenant (a memory
/// op or a submission).  Refcounts track connection claims so
/// [`release_tenant`] can retire a tenant exactly once.
struct TenantDirectory {
    /// Tenant name -> id (named tenants only).
    ids: HashMap<String, usize>,
    /// Connection -> bound tenant id.
    conn: HashMap<u64, usize>,
    /// Tenant id -> live connection claims.
    refs: HashMap<usize, usize>,
    next: usize,
}

impl TenantDirectory {
    fn new() -> TenantDirectory {
        TenantDirectory {
            ids: HashMap::new(),
            conn: HashMap::new(),
            refs: HashMap::new(),
            next: 0,
        }
    }

    /// The connection's tenant, lazily creating a private anonymous
    /// tenant (with its refcount claim) on first use — the single
    /// creation path shared by the memory plane and submission, so the
    /// Goodbye release can never underflow.
    fn of_conn(&mut self, user: u64) -> usize {
        if let Some(&t) = self.conn.get(&user) {
            return t;
        }
        let t = self.next;
        self.next += 1;
        self.conn.insert(user, t);
        *self.refs.entry(t).or_insert(0) += 1;
        t
    }

    /// The id of a named tenant, creating one on first bind.
    fn id_of_name(&mut self, name: &str) -> usize {
        if let Some(&id) = self.ids.get(name) {
            return id;
        }
        let id = self.next;
        self.next += 1;
        self.ids.insert(name.to_string(), id);
        id
    }
}

/// Why a handle failed to resolve: a *stale or forged* handle (never
/// valid, or freed/reclaimed — generation mismatch) versus a live
/// buffer owned by *another* tenant.  Deliberately, the denial never
/// names the owning tenant — that would leak cross-domain information.
enum HandleError {
    Invalid(BufferHandle),
    Denied(BufferHandle),
}

impl HandleError {
    fn into_value(self) -> Value {
        match self {
            HandleError::Invalid(h) => err_val(&format!("invalid buffer handle {h}")),
            HandleError::Denied(h) => {
                denied_val(&format!("access denied: {h} is not owned by this tenant"))
            }
        }
    }
}

/// One live buffer: its generation (stale-handle detection), owning
/// tenant, cluster-wide physical address and length.
struct BufEntry {
    generation: u32,
    tenant: usize,
    addr: u64,
    bytes: usize,
    live: bool,
}

/// The daemon-wide buffer table: a generational slab mapping opaque
/// [`BufferHandle`]s to (tenant, address, length).  The cluster's
/// arenas evolve in lockstep, so one table serves every board.  Slots
/// are reused with a bumped generation: a freed (or arena-reclaimed)
/// handle can never resolve again, even if its slot is recycled.
struct BufTable {
    entries: Vec<BufEntry>,
    free: Vec<usize>,
}

impl BufTable {
    fn new() -> BufTable {
        BufTable { entries: Vec::new(), free: Vec::new() }
    }

    fn insert(&mut self, tenant: usize, addr: u64, bytes: usize) -> BufferHandle {
        match self.free.pop() {
            Some(slot) => {
                let e = &mut self.entries[slot];
                // Generation 0 is never minted, so handle 0 (and any
                // zero-generation forgery) is invalid by construction.
                e.generation = e.generation.wrapping_add(1).max(1);
                e.tenant = tenant;
                e.addr = addr;
                e.bytes = bytes;
                e.live = true;
                BufferHandle::from_parts(slot as u32, e.generation)
            }
            None => {
                let slot = self.entries.len();
                self.entries.push(BufEntry {
                    generation: 1,
                    tenant,
                    addr,
                    bytes,
                    live: true,
                });
                BufferHandle::from_parts(slot as u32, 1)
            }
        }
    }

    /// Resolve a handle *for* a tenant: the ownership gate every
    /// memory RPC and job submission passes through.
    fn resolve(&self, h: BufferHandle, tenant: usize) -> Result<(u64, usize), HandleError> {
        let e = self
            .entries
            .get(h.slot() as usize)
            .filter(|e| e.live && e.generation == h.generation())
            .ok_or(HandleError::Invalid(h))?;
        if e.tenant != tenant {
            return Err(HandleError::Denied(h));
        }
        Ok((e.addr, e.bytes))
    }

    /// Resolve-then-invalidate (the `free` path).  The slot is
    /// recycled; the generation bump happens at the next insert.
    fn remove(&mut self, h: BufferHandle, tenant: usize) -> Result<(u64, usize), HandleError> {
        let (addr, bytes) = self.resolve(h, tenant)?;
        self.entries[h.slot() as usize].live = false;
        self.free.push(h.slot() as usize);
        Ok((addr, bytes))
    }

    /// Invalidate every live handle of a retired tenant (the buffer
    /// table half of arena teardown); returns how many were dropped.
    fn reclaim_tenant(&mut self, tenant: usize) -> usize {
        let mut n = 0;
        for (slot, e) in self.entries.iter_mut().enumerate() {
            if e.live && e.tenant == tenant {
                e.live = false;
                self.free.push(slot);
                n += 1;
            }
        }
        n
    }
}

/// A job past the submission trust boundary: operand handles already
/// resolved (ownership-checked) to physical addresses, so the
/// scheduling and execution pipeline never re-resolves — and a handle
/// freed mid-flight cannot dangle into another tenant's later
/// allocation at dispatch time.
struct ExecJob {
    accname: String,
    params: Vec<(String, u64)>,
    tiles: usize,
}

/// Authentication state (present only when the daemon was started
/// with pre-registered tenants): the admin token gating
/// `register-tenant`, and each tenant's minted bearer token checked at
/// `session` bind.  Shared between the daemon handle (token
/// accessors) and the dispatcher (verification).
pub(crate) struct AuthState {
    admin: String,
    tokens: HashMap<String, String>,
    rng: crate::testutil::Rng,
}

impl AuthState {
    fn new() -> AuthState {
        use std::collections::hash_map::RandomState;
        use std::hash::{BuildHasher, Hasher};
        // Seed from the OS-randomised hasher state — no fixed seed, so
        // tokens are not guessable across daemon restarts.
        let seed = RandomState::new().build_hasher().finish();
        let mut rng = crate::testutil::Rng::new(seed);
        let admin = Self::mint_with(&mut rng);
        AuthState { admin, tokens: HashMap::new(), rng }
    }

    fn mint_with(rng: &mut crate::testutil::Rng) -> String {
        format!("{:016x}{:016x}", rng.next_u64(), rng.next_u64())
    }

    fn mint(&mut self) -> String {
        Self::mint_with(&mut self.rng)
    }
}

/// Failed-auth token bucket: generous enough that an honest client
/// retyping a token never sees it, tight enough that brute-forcing a
/// 128-bit bearer token is hopeless.
const AUTH_FAIL_BURST: f64 = 8.0;
const AUTH_FAIL_PER_SEC: f64 = 1.0;
/// Audit-read bucket: the audit RPC walks (a tenant-filtered view of)
/// the merged decision log, the most expensive read on the control
/// plane — cap how fast one connection can spin on it.
const AUDIT_BURST: f64 = 32.0;
const AUDIT_PER_SEC: f64 = 8.0;

/// One token bucket, refilled continuously by wall-clock time.
struct CtlBucket {
    tokens: f64,
    last: Instant,
}

impl CtlBucket {
    fn new(burst: f64) -> CtlBucket {
        CtlBucket { tokens: burst, last: Instant::now() }
    }

    /// Take one token; `Err(retry_after_ms)` when the bucket is dry.
    fn try_take(&mut self, burst: f64, per_sec: f64) -> Result<(), u64> {
        let now = Instant::now();
        let refill = now.duration_since(self.last).as_secs_f64() * per_sec;
        self.tokens = (self.tokens + refill).min(burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            Err((((1.0 - self.tokens) / per_sec) * 1000.0).ceil().max(1.0) as u64)
        }
    }
}

/// Control-plane rate limiting, per connection.  Two RPC families an
/// adversarial client can spin on are bucketed: failed authentication
/// attempts (`session` binds with a bad token, `register-tenant` with
/// a bad admin token — successful ones are never charged) and audit
/// log reads (charged per read).  Exhaustion answers with a structured
/// `busy{retry_after_ms}` reply instead of servicing the request;
/// buckets are dropped with the connection's Goodbye.
#[derive(Default)]
struct CtlGovernor {
    auth: HashMap<u64, CtlBucket>,
    audit: HashMap<u64, CtlBucket>,
}

impl CtlGovernor {
    /// Charge a failed authentication attempt by connection `user`.
    fn charge_auth_fail(&mut self, user: u64) -> Result<(), u64> {
        self.auth
            .entry(user)
            .or_insert_with(|| CtlBucket::new(AUTH_FAIL_BURST))
            .try_take(AUTH_FAIL_BURST, AUTH_FAIL_PER_SEC)
    }

    /// Charge an audit-log read by connection `user`.
    fn charge_audit(&mut self, user: u64) -> Result<(), u64> {
        self.audit
            .entry(user)
            .or_insert_with(|| CtlBucket::new(AUDIT_BURST))
            .try_take(AUDIT_BURST, AUDIT_PER_SEC)
    }

    /// The connection closed: drop its buckets.
    fn forget(&mut self, user: u64) {
        self.auth.remove(&user);
        self.audit.remove(&user);
    }
}

/// Declarative daemon configuration — the builder behind every
/// `start_*` constructor.  `tenants` is the authentication switch:
/// naming tenants here mints a bearer token for each (plus an admin
/// token) and makes the `session` bind require one.
pub struct DaemonConfig {
    pub boards: Vec<ShellBoard>,
    pub catalog: Catalog,
    pub default_policy: Policy,
    pub placement: PlacementKind,
    pub admission: AdmissionConfig,
    pub max_connections: usize,
    /// Number of reactor shards in the network plane.  `1` (the
    /// default) is the single-threaded reactor — byte-identical to the
    /// pre-sharding daemon.  `N > 1` spawns a dedicated acceptor thread
    /// that deals connections round-robin across `N` reactor threads
    /// (clamped to [`MAX_SHARDS`]).
    pub reactor_shards: usize,
    pub faults: Option<FaultPlan>,
    /// Tenant names to register at startup with minted tokens;
    /// non-empty switches the daemon into authenticated mode.
    pub tenants: Vec<String>,
    /// Replay a recorded [`Scenario`] through the dispatcher's
    /// virtual-time loop (`fos daemon --scenario <spec>`): every trace
    /// record becomes a clientless submission at its virtual arrival
    /// time, interleaving with live RPC traffic and any fault plan —
    /// the same scenario driven through
    /// [`crate::sched::simulate_cluster`] replays the identical
    /// decision sequence.
    pub scenario: Option<Scenario>,
    /// Nondeterminism-resolution strategy for the dispatcher's DES
    /// loop (`fos daemon --order seed=N`); identity = byte-identical
    /// to the fixed orderings.
    pub order: OrderStrategy,
}

impl DaemonConfig {
    pub fn new(boards: &[ShellBoard], catalog: Catalog) -> DaemonConfig {
        DaemonConfig {
            boards: boards.to_vec(),
            catalog,
            default_policy: Policy::Elastic,
            placement: PlacementKind::Locality,
            admission: AdmissionConfig::default(),
            max_connections: DEFAULT_MAX_CONNECTIONS,
            reactor_shards: 1,
            faults: None,
            tenants: Vec::new(),
            scenario: None,
            order: OrderStrategy::default(),
        }
    }

    pub fn policy(mut self, p: Policy) -> DaemonConfig {
        self.default_policy = p;
        self
    }

    pub fn placement(mut self, p: PlacementKind) -> DaemonConfig {
        self.placement = p;
        self
    }

    pub fn admission(mut self, a: AdmissionConfig) -> DaemonConfig {
        self.admission = a;
        self
    }

    pub fn max_connections(mut self, n: usize) -> DaemonConfig {
        self.max_connections = n;
        self
    }

    pub fn reactor_shards(mut self, n: usize) -> DaemonConfig {
        self.reactor_shards = n;
        self
    }

    pub fn faults(mut self, f: FaultPlan) -> DaemonConfig {
        self.faults = Some(f);
        self
    }

    pub fn tenants(mut self, names: &[&str]) -> DaemonConfig {
        self.tenants = names.iter().map(|n| n.to_string()).collect();
        self
    }

    pub fn scenario(mut self, sc: Scenario) -> DaemonConfig {
        self.scenario = Some(sc);
        self
    }

    pub fn order(mut self, order: OrderStrategy) -> DaemonConfig {
        self.order = order;
        self
    }
}

/// A running daemon instance.
pub struct Daemon {
    pub socket_path: PathBuf,
    boards: Vec<ShellBoard>,
    stats: Arc<DaemonStats>,
    tx: mpsc::SyncSender<Msg>,
    stop: Arc<AtomicBool>,
    /// One waker per network-plane thread: every reactor shard plus,
    /// when sharded, the acceptor.  Shutdown pokes them all.
    net_wakers: Vec<Waker>,
    net_handles: Vec<std::thread::JoinHandle<()>>,
    dispatch_handle: Option<std::thread::JoinHandle<()>>,
    /// `Some` iff the daemon runs in authenticated mode.
    auth: Option<Arc<Mutex<AuthState>>>,
}

impl Daemon {
    /// Start the daemon under the resource-elastic default policy.
    pub fn start(
        socket_path: impl AsRef<Path>,
        board: ShellBoard,
        catalog: Catalog,
    ) -> io::Result<Daemon> {
        Self::start_with_policy(socket_path, board, catalog, Policy::Elastic)
    }

    /// Start a single-board daemon (a one-board cluster).
    /// `default_policy` routes tenants that never call
    /// `FpgaRpc::set_policy`.
    pub fn start_with_policy(
        socket_path: impl AsRef<Path>,
        board: ShellBoard,
        catalog: Catalog,
        default_policy: Policy,
    ) -> io::Result<Daemon> {
        Self::start_cluster(socket_path, &[board], catalog, default_policy, PlacementKind::Locality)
    }

    /// Start a multi-fabric daemon with the default admission pipeline
    /// and connection cap (see [`Daemon::start_cluster_configured`]).
    pub fn start_cluster(
        socket_path: impl AsRef<Path>,
        boards: &[ShellBoard],
        catalog: Catalog,
        default_policy: Policy,
        placement: PlacementKind,
    ) -> io::Result<Daemon> {
        Self::start_cluster_configured(
            socket_path,
            boards,
            catalog,
            default_policy,
            placement,
            AdmissionConfig::default(),
            DEFAULT_MAX_CONNECTIONS,
        )
    }

    /// Start a multi-fabric daemon: bind the socket, bring up one FPGA
    /// (`Cynq`) per entry of `boards` — heterogeneous mixes welcome —
    /// and spawn the accept loop plus one dispatcher thread driving a
    /// scheduler shard per board, with `placement` routing every
    /// request to a board at ingest time.  `admission` tunes the
    /// tenant-aware admission pipeline (bounded queues, DRR quantum,
    /// ingest batch cap); `max_connections` caps the live connection
    /// table (excess clients get a structured busy reject).
    pub fn start_cluster_configured(
        socket_path: impl AsRef<Path>,
        boards: &[ShellBoard],
        catalog: Catalog,
        default_policy: Policy,
        placement: PlacementKind,
        admission: AdmissionConfig,
        max_connections: usize,
    ) -> io::Result<Daemon> {
        Self::start_cluster_with_faults(
            socket_path,
            boards,
            catalog,
            default_policy,
            placement,
            admission,
            max_connections,
            None,
        )
    }

    /// [`Daemon::start_cluster_configured`] with a deterministic
    /// [`FaultPlan`] injected into the dispatcher's virtual-time loop —
    /// soak testing against board outages, reconfiguration failures and
    /// transient run errors (`fos daemon --fault-plan <spec>`).  The
    /// same plan driven through [`crate::sched::simulate_cluster`]
    /// replays the identical fault (and recovery decision) sequence.
    #[allow(clippy::too_many_arguments)]
    pub fn start_cluster_with_faults(
        socket_path: impl AsRef<Path>,
        boards: &[ShellBoard],
        catalog: Catalog,
        default_policy: Policy,
        placement: PlacementKind,
        admission: AdmissionConfig,
        max_connections: usize,
        faults: Option<FaultPlan>,
    ) -> io::Result<Daemon> {
        Self::start_configured(
            socket_path,
            DaemonConfig {
                boards: boards.to_vec(),
                catalog,
                default_policy,
                placement,
                admission,
                max_connections,
                faults,
                tenants: Vec::new(),
                scenario: None,
                order: OrderStrategy::default(),
            },
        )
    }

    /// Start a daemon from a [`DaemonConfig`] — the constructor every
    /// other `start_*` wrapper delegates to.  Naming tenants in the
    /// config mints their bearer tokens (read them back via
    /// [`Daemon::tenant_token`] / [`Daemon::admin_token`]) and makes
    /// the `session` bind require one.
    pub fn start_configured(
        socket_path: impl AsRef<Path>,
        cfg: DaemonConfig,
    ) -> io::Result<Daemon> {
        assert!(!cfg.boards.is_empty(), "a cluster needs at least one board");
        // A scenario must be fully resolvable before the dispatcher
        // starts: an unknown accelerator (or pinned variant) in a trace
        // is a startup error, not a mid-replay panic.
        if let Some(sc) = &cfg.scenario {
            for e in sc.events() {
                let a = cfg.catalog.get(&e.accel).ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::InvalidInput,
                        format!("scenario references unknown accelerator {:?}", e.accel),
                    )
                })?;
                if let Some(v) = &e.variant {
                    if !a.variants.iter().any(|av| &av.name == v) {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidInput,
                            format!("scenario pins unknown variant {:?} of {:?}", v, e.accel),
                        ));
                    }
                }
            }
        }
        let socket_path = socket_path.as_ref().to_path_buf();
        let _ = std::fs::remove_file(&socket_path);
        let listener = UnixListener::bind(&socket_path)?;
        let cynqs = cfg
            .boards
            .iter()
            .map(|&b| Cynq::open(b, cfg.catalog.clone()))
            .collect::<Result<Vec<Cynq>, _>>()
            .map_err(|e| io::Error::new(io::ErrorKind::Other, e.to_string()))?;

        let stats = Arc::new(DaemonStats::for_boards(&cfg.boards));
        let stop = Arc::new(AtomicBool::new(false));
        // Bounded ingest: with N shards feeding the one dispatcher the
        // queue must not become an unbounded buffer under overload.
        // Capacity covers every admissible connection with one request
        // in flight plus one parked Goodbye — the write-one-read-one
        // protocol discipline means a connection never has more than
        // one decoded message in the queue at a time, so the bound is
        // never hit in steady state and exists purely as a backstop.
        let ingest_bound = cfg.max_connections.saturating_mul(2).max(1024);
        let (tx, rx) = mpsc::sync_channel::<Msg>(ingest_bound);

        let auth = if cfg.tenants.is_empty() {
            None
        } else {
            let mut a = AuthState::new();
            for name in &cfg.tenants {
                let tok = a.mint();
                a.tokens.insert(name.clone(), tok);
            }
            Some(Arc::new(Mutex::new(a)))
        };

        let dispatch_handle = {
            let stats = stats.clone();
            let auth = auth.clone();
            let (policy, placement, admission, faults) =
                (cfg.default_policy, cfg.placement, cfg.admission, cfg.faults);
            let (scenario, order) = (cfg.scenario, cfg.order);
            std::thread::Builder::new().name("fos-dispatch".into()).spawn(move || {
                dispatcher(
                    cynqs, rx, stats, policy, placement, admission, faults, scenario, order, auth,
                )
            })?
        };

        // The network plane: event-driven reactor threads hold every
        // connection in per-shard slabs (no thread per client), poll
        // for readiness, frame requests into reusable buffers and ship
        // decoded messages to the dispatcher.  Past `max_connections`
        // live entries (a global cap shared by all shards) a new
        // client gets a structured busy reject.
        let nshards = cfg.reactor_shards.clamp(1, MAX_SHARDS);
        let mut net_wakers = Vec::new();
        let mut net_handles = Vec::new();
        if nshards == 1 {
            // Single shard: the reactor owns the listener directly —
            // the pre-sharding topology, byte-identical.
            let (reactor, waker) = Reactor::new(
                listener,
                tx.clone(),
                stats.clone(),
                stop.clone(),
                cfg.max_connections,
            )?;
            net_wakers.push(waker);
            net_handles.push(
                std::thread::Builder::new()
                    .name("fos-reactor".into())
                    .spawn(move || reactor.run())?,
            );
        } else {
            // N shards: a dedicated acceptor owns the listener and
            // deals accepted streams round-robin into per-shard
            // handoff rings; each shard admits from its ring.  The
            // live-connection cap is shared across shards.
            let live = Arc::new(AtomicUsize::new(0));
            let mut acceptor_lanes = Vec::with_capacity(nshards);
            for shard in 0..nshards {
                let (htx, hrx) = mpsc::channel();
                let (reactor, waker) = Reactor::shard(
                    shard,
                    nshards,
                    hrx,
                    tx.clone(),
                    stats.clone(),
                    stop.clone(),
                    cfg.max_connections,
                    live.clone(),
                )?;
                acceptor_lanes.push((htx, waker.clone()));
                net_wakers.push(waker);
                net_handles.push(
                    std::thread::Builder::new()
                        .name(format!("fos-reactor-{shard}"))
                        .spawn(move || reactor.run())?,
                );
            }
            let (acceptor, acceptor_waker) = Acceptor::new(listener, acceptor_lanes, stop.clone())?;
            net_wakers.push(acceptor_waker);
            net_handles.push(
                std::thread::Builder::new()
                    .name("fos-acceptor".into())
                    .spawn(move || acceptor.run())?,
            );
        }

        Ok(Daemon {
            socket_path,
            boards: cfg.boards,
            stats,
            tx,
            stop,
            net_wakers,
            net_handles,
            dispatch_handle: Some(dispatch_handle),
            auth,
        })
    }

    /// The admin token (authenticated mode only) — gates the
    /// `register-tenant` control RPC.
    pub fn admin_token(&self) -> Option<String> {
        self.auth.as_ref().map(|a| a.lock().unwrap().admin.clone())
    }

    /// The minted bearer token of a registered tenant, or `None` when
    /// the daemon is open-mode or the tenant is unknown.
    pub fn tenant_token(&self, name: &str) -> Option<String> {
        self.auth.as_ref().and_then(|a| a.lock().unwrap().tokens.get(name).cloned())
    }

    pub fn stats(&self) -> &DaemonStats {
        &self.stats
    }

    /// The boards this daemon dispatches to (index order = board ids).
    pub fn boards(&self) -> &[ShellBoard] {
        &self.boards
    }

    /// Snapshot of the merged cluster decision log in dispatch order
    /// (the most recent entries, ring-capped). For a single-board
    /// daemon this is the board's log. Empty once the dispatcher has
    /// stopped.
    pub fn decision_log(&self) -> Vec<Decision> {
        self.decision_log_query(None, None)
    }

    /// The last `n` merged decisions only — what monitoring loops
    /// should poll.  The dispatcher clones only the tail (O(n)
    /// positioning, never a full-ring scan).
    pub fn decision_log_tail(&self, n: usize) -> Vec<Decision> {
        self.decision_log_query(None, Some(n))
    }

    /// One board's ordered decision log — the per-shard sequence the
    /// cluster parity test compares against the simulator's.
    pub fn board_decision_log(&self, board: usize) -> Vec<Decision> {
        self.decision_log_query(Some(board), None)
    }

    /// The merged cluster decision log WITH board tags, in global
    /// dispatch order — the `(board, decision)` sequence the fault
    /// parity test compares against `ClusterSimResult::merged`.
    pub fn merged_decision_log(&self) -> Vec<(usize, Decision)> {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Msg::QueryMergedTagged { reply: rtx }).is_err() {
            return Vec::new();
        }
        rrx.recv().unwrap_or_default()
    }

    fn decision_log_query(&self, board: Option<usize>, limit: Option<usize>) -> Vec<Decision> {
        let (rtx, rrx) = mpsc::channel();
        if self.tx.send(Msg::QueryLog { board, limit, reply: rtx }).is_err() {
            return Vec::new();
        }
        rrx.recv().unwrap_or_default()
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake every network-plane thread's poll wait (each shard plus
        // the acceptor when sharded): they re-check the stop flag at
        // the top of every loop, close their connections (emitting
        // their Goodbyes) and exit — all before the dispatcher sees
        // Stop, so no slot retirement is lost.
        for w in &self.net_wakers {
            w.wake_force();
        }
        for h in self.net_handles.drain(..) {
            let _ = h.join();
        }
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.dispatch_handle.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.socket_path);
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// A submitted proto job awaiting its (next) scheduling decision.  A
/// preempted job re-enters this map carrying the real/modelled time its
/// completed slices already consumed, plus any failure to report once
/// its remainder finally completes.
struct PendingJob {
    job: ExecJob,
    batch: usize,
    /// Real execution µs accumulated by earlier preempted slices.
    carry_us: f64,
    /// Modelled virtual µs consumed by earlier preempted slices.
    carry_modelled_us: f64,
    /// A slice already failed; report at the final completion.
    failed: Option<String>,
}

impl PendingJob {
    fn new(job: ExecJob, batch: usize) -> PendingJob {
        PendingJob { job, batch, carry_us: 0.0, carry_modelled_us: 0.0, failed: None }
    }
}

/// A dispatched decision whose execution is deferred to its virtual
/// completion — or to an earlier preemption of its anchor, which runs
/// only the completed slice and checkpoints the rest.  Deferral is what
/// lets the daemon split work *exactly* where the core's `Preempt`
/// decision says, instead of having eagerly computed the whole batch.
struct Inflight {
    d: Decision,
    /// Board the decision was dispatched on (its `Cynq`, resident map
    /// and snapshot store).
    board: usize,
    job: ExecJob,
    batch: usize,
    /// Module handle for execution; `None` when the (re)load failed —
    /// `err` below then surfaces at completion.
    handle: Option<LoadedAccel>,
    err: Option<String>,
    /// Virtual dispatch time and modelled service time.
    start_ns: u64,
    lat_ns: u64,
    carry_us: f64,
    carry_modelled_us: f64,
}

/// Sentinel "anchor" for preemption-check tick entries in the
/// completion heap: never registered in `inflight`, so popping one only
/// advances the virtual clock and triggers a round — exactly the
/// simulator's `Event::Tick`.
const TICK_ANCHOR: usize = usize::MAX;

/// Sentinel anchor: injected board outage starts (the heap entry's
/// board field names the victim) — the simulator's `BoardDown` event.
const DOWN_ANCHOR: usize = usize::MAX - 1;

/// Sentinel anchor: outage end, the board rejoins the routable set.
const REVIVE_ANCHOR: usize = usize::MAX - 2;

/// Sentinel anchor: a reconfiguration-retry backoff expired — wakes
/// the loop so `release_retries` runs at the right virtual time.
const RETRY_ANCHOR: usize = usize::MAX - 3;

/// Sentinel anchor: a scenario-trace arrival (the heap entry's board
/// field indexes `replay_events`) — the simulator's `Event::Arrival`
/// (and, after `Busy` backpressure, its `Event::Retry`).
const ARRIVAL_ANCHOR: usize = usize::MAX - 4;

/// One board's hardware-side state: its `Cynq` stack, the resident
/// module map, the dispatch-in-flight index, the register-file
/// snapshot store (keyed by the *shard's* checkpoint ids — ids are
/// per-shard, so each board keeps its own map) and its preemption
/// tick.
struct BoardHw {
    cynq: Cynq,
    /// anchor -> (handle, span) of the modules on this fabric.
    resident: HashMap<usize, (LoadedAccel, usize)>,
    /// anchor -> seq of the dispatch currently running there.
    running_seq: HashMap<usize, u64>,
    /// checkpoint id -> register-file + progress snapshot (the
    /// hardware half of this shard's checkpoint store).
    snapshots: HashMap<u64, AccelSnapshot>,
    /// One pending preemption-check tick at a time (sim parity).
    next_tick: Option<u64>,
}

/// The dispatcher: owns every board's FPGA and drives the shared
/// cluster core (one scheduler shard per board).  Blocks on the
/// channel when idle or paused; while work is in flight it alternates
/// message draining, per-board scheduling rounds and virtual-time
/// completion replay — never a hot spin.
///
/// Execution is *deferred*: a decision mirrors its reconfiguration onto
/// its board immediately (that is when the fabric changes), but
/// register programming and tile compute run when the decision's
/// virtual completion is replayed.  A `Preempt` decision arriving
/// before that point cancels the completion, runs only the tiles the
/// virtual clock says finished, and checkpoints the accelerator —
/// so preempted work is split, never recomputed.  Completions from
/// every board share one virtual-time heap, and every event batch
/// triggers a round on each board in index order — exactly the
/// cluster simulator's loop, which is what keeps per-shard decision
/// parity.
#[allow(clippy::too_many_arguments)]
fn dispatcher(
    cynqs: Vec<Cynq>,
    rx: mpsc::Receiver<Msg>,
    stats: Arc<DaemonStats>,
    policy: Policy,
    placement: PlacementKind,
    admission: AdmissionConfig,
    faults: Option<FaultPlan>,
    scenario: Option<Scenario>,
    order: OrderStrategy,
    auth: Option<Arc<Mutex<AuthState>>>,
) {
    let boards: Vec<ShellBoard> = cynqs.iter().map(|c| c.shell.board).collect();
    let n_boards = boards.len();
    let catalog = cynqs[0].catalog.clone();
    let mut cluster = ClusterCore::new(&boards, &catalog, policy, placement);
    // Weighted memory-bandwidth partitioning is a QoS knob carried by
    // the admission config; the cores consume it in their cost models.
    cluster.set_bw_partition(admission.bw_partition);
    // Interned-name resolution at the RPC/hardware boundary: the same
    // deterministic table every scheduler core derives from the shared
    // catalog, so a `Sym` carried by any decision resolves here.
    let symbols = SymbolTable::from_catalog(&catalog);
    // The tenant-aware admission stage: per-tenant bounded queues
    // feeding batched DRR ingest (the same pipeline the simulator
    // drives at the same point of the round lifecycle).
    let mut admit = AdmissionPipeline::new(admission);
    // Tenant identity: named tenants (the `session` RPC) share an id
    // across connections; anonymous connections get a private one.
    let mut tenants = TenantDirectory::new();
    // Per-connection control-plane rate limits (failed auth attempts,
    // audit reads) — see [`CtlGovernor`].
    let mut ctl = CtlGovernor::default();
    // The tenant-scoped buffer table: every client-visible buffer
    // lives here, keyed by opaque generational handle.
    let mut bufs = BufTable::new();
    // Async submission tickets (see `BatchSink::Ticket`), plus an O(1)
    // per-connection open-ticket count for the MAX_OPEN_TICKETS cap.
    let mut tickets: HashMap<u64, Ticket> = HashMap::new();
    let mut open_tickets: HashMap<u64, usize> = HashMap::new();
    let mut next_ticket = 0u64;
    let mut hws: Vec<BoardHw> = cynqs
        .into_iter()
        .map(|cynq| BoardHw {
            cynq,
            resident: HashMap::new(),
            running_seq: HashMap::new(),
            snapshots: HashMap::new(),
            next_tick: None,
        })
        .collect();
    // Live batches only — finished ones are removed, so a long-lived
    // daemon does not accumulate per-job state.
    let mut batches: HashMap<usize, Batch> = HashMap::new();
    let mut next_batch = 0usize;
    let mut pending: HashMap<u64, PendingJob> = HashMap::new();
    let mut next_token = 0u64;
    // Daemon connection id -> scheduler slot; slots are recycled on
    // Goodbye so core state is bounded by peak concurrent tenants.
    let mut user_index: HashMap<u64, usize> = HashMap::new();
    let mut free_slots: std::collections::BTreeSet<usize> = std::collections::BTreeSet::new();
    let mut next_fresh = 0usize;
    // State-changing messages deferred from mid-round draining (see
    // the round loop): processed before new channel messages.
    let mut inbox: VecDeque<Msg> = VecDeque::new();
    // (virtual completion time, seq, board, anchor) — the cluster
    // simulator's heap.
    let mut completions: BinaryHeap<Reverse<(u64, u64, usize, usize)>> = BinaryHeap::new();
    // seq -> deferred execution context of a dispatched decision.  An
    // entry missing at completion-pop means the dispatch was preempted
    // (or the entry is a tick): the pop only advances virtual time.
    let mut inflight: HashMap<u64, Inflight> = HashMap::new();
    let mut seq = 0u64;
    let mut vnow = 0u64;
    let mut paused = false;
    // Fault injection: the plan's draw counters advance as decisions
    // and completions are processed — the same consumption points as
    // the simulator's, so a shared plan replays identically.  Outage
    // sentinels are parked until the first submission so the virtual
    // clock (which only advances with work) anchors them the same way
    // the simulator's t=0 arrivals do.
    let mut plan = faults;
    let mut fault_events: Vec<(u64, usize, usize)> = plan
        .as_ref()
        .map(|p| {
            p.outages()
                .iter()
                .filter(|o| o.board < n_boards)
                .flat_map(|o| {
                    [(o.at_ns, o.board, DOWN_ANCHOR), (o.revive_at_ns(), o.board, REVIVE_ANCHOR)]
                })
                .collect()
        })
        .unwrap_or_default();
    // Register-file snapshots drained off a failed board before any
    // healthy shard could adopt them, keyed by job token until a
    // `release_retries` reports the adoption.
    let mut parked_snaps: HashMap<u64, AccelSnapshot> = HashMap::new();
    // A scheduling round is due: new admissions, a policy change or a
    // virtual-time advance happened since the last one. Mirrors the
    // simulator's one-round-per-event-batch cadence, which keeps the
    // decision (and skip-counter) sequences identical on both paths.
    let mut round_due = false;

    // Scenario replay: lower the trace into the same Workload the
    // simulator consumes and arm one ARRIVAL_ANCHOR sentinel per job at
    // its virtual arrival time — the heap entry's board field indexes
    // `replay_events` (job, remaining-requests).  Seq assignment
    // mirrors `simulate_cluster` exactly: arrivals 0..n-1 first, then
    // the fault plan's outage pairs, so equal-timestamp batches sort
    // (and permute) identically on both harnesses.
    let replay: Option<Workload> =
        scenario.map(|sc| sc.to_workload()).filter(|w| !w.jobs.is_empty());
    let mut replay_events: Vec<(usize, usize)> = Vec::new();
    let mut scenario_batch = usize::MAX;
    if let Some(w) = &replay {
        for &(u, q) in &w.qos {
            admit.set_qos(u, q);
            cluster.set_tenant_weight(u, q.weight);
        }
        // Trace tenants own scheduler slots 0..users-1 (tenant = user,
        // the simulator's rule); live connections get fresh slots
        // above them so the two populations never collide.
        next_fresh = next_fresh.max(w.users());
        tenants.next = tenants.next.max(w.users());
        // All trace jobs share one clientless batch: nothing to reply
        // to, but `remaining` still gates the stall guard's view of
        // outstanding work.
        scenario_batch = next_batch;
        next_batch += 1;
        batches.insert(
            scenario_batch,
            Batch {
                sink: BatchSink::Discard,
                remaining: w.total_requests(),
                latencies_us: Vec::new(),
                modelled_us: Vec::new(),
                error: None,
            },
        );
        for (j, spec) in w.jobs.iter().enumerate() {
            completions.push(Reverse((spec.arrival, seq, replay_events.len(), ARRIVAL_ANCHOR)));
            seq += 1;
            replay_events.push((j, spec.requests));
        }
        // The scenario's arrivals anchor the virtual clock from t=0, so
        // the fault sentinels arm right now (first-Submit arming would
        // misorder their seqs relative to the simulator's).
        for (t, b, anchor) in fault_events.drain(..) {
            completions.push(Reverse((t, seq, b, anchor)));
            seq += 1;
        }
    }

    'outer: loop {
        // Block when idle or paused (no busy-spin); drain without
        // blocking while a round is due or completions are in flight.
        let idle = paused || (!round_due && completions.is_empty());
        let msg = match inbox.pop_front() {
            Some(m) => Some(m),
            None if idle => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break 'outer,
            },
            None => rx.try_recv().ok(),
        };
        if let Some(msg) = msg {
            let Some(msg) = handle_cheap(
                msg,
                &mut hws,
                &cluster,
                &admit,
                &mut tickets,
                &mut open_tickets,
                &mut paused,
                &mut user_index,
                &mut free_slots,
                &mut next_fresh,
                &mut tenants,
                &mut bufs,
                &auth,
                &mut ctl,
                &symbols,
            ) else {
                continue;
            };
            match msg {
                Msg::Stop => break 'outer,
                Msg::Goodbye { user } => {
                    // Recycle the departed connection's scheduler slot
                    // so a long-lived daemon's per-user state is
                    // bounded by peak concurrency, not connections-ever.
                    if let Some(slot) = user_index.remove(&user) {
                        // Queued-but-unadmitted requests first (their
                        // in-flight tokens were never taken)…
                        for r in admit.drop_user(slot) {
                            if let Some(p) = pending.remove(&r.job) {
                                fail_job(
                                    &mut batches,
                                    &mut tickets,
                                    &mut open_tickets,
                                    p.batch,
                                    "client disconnected".into(),
                                );
                            }
                        }
                        // …then the scheduler-side queues (tokens come
                        // back through the pipeline).
                        for (b, req) in cluster.retire_user(slot) {
                            admit.complete(req.tenant);
                            if let Some(id) = req.resume {
                                hws[b].snapshots.remove(&id); // orphaned checkpoint
                            }
                            parked_snaps.remove(&req.job);
                            if let Some(p) = pending.remove(&req.job) {
                                fail_job(
                                    &mut batches,
                                    &mut tickets,
                                    &mut open_tickets,
                                    p.batch,
                                    "client disconnected".into(),
                                );
                            }
                        }
                        free_slots.insert(slot);
                    }
                    // Release the tenant binding; a tenant with no
                    // connections left is retired from the pipeline
                    // once its remaining work drains, and its name
                    // mapping is dropped so the id table stays bounded
                    // by *live* tenants, not names-ever.  The last
                    // claim also tears the tenant's isolation domain
                    // down: its arena is reclaimed on every board and
                    // all its buffer handles are invalidated.
                    if let Some(t) = tenants.conn.remove(&user) {
                        if release_tenant(&mut tenants.ids, &mut tenants.refs, &mut admit, t) {
                            reclaim_arena(&mut hws, &mut bufs, t);
                        }
                    }
                    // Unclaimed tickets of the departed connection.
                    tickets.retain(|_, t| t.user != user);
                    open_tickets.remove(&user);
                    ctl.forget(user);
                }
                Msg::Session { user, tenant, token, weight, max_inflight, reply } => {
                    // Authenticated mode: a bind must present the
                    // tenant's bearer token; a wrong or missing one is
                    // refused with a structured `denied` reply and the
                    // connection keeps its previous binding.
                    if let Some(a) = auth.as_ref() {
                        let a = a.lock().unwrap();
                        let good = a
                            .tokens
                            .get(&tenant)
                            .is_some_and(|t| token.as_deref() == Some(t.as_str()));
                        if !good {
                            // Failed binds are rate-limited per
                            // connection: past the burst a brute-force
                            // loop sees `busy{retry_after_ms}`, not
                            // another oracle answer.
                            reply.send(match ctl.charge_auth_fail(user) {
                                Ok(()) => denied_val(&format!(
                                    "tenant bind denied: bad or missing token for {tenant:?}"
                                )),
                                Err(ms) => busy_val("too many failed session binds", ms),
                            });
                            continue;
                        }
                    }
                    let id = tenants.id_of_name(&tenant);
                    let prev = tenants.conn.insert(user, id);
                    if prev != Some(id) {
                        *tenants.refs.entry(id).or_insert(0) += 1;
                        if let Some(old) = prev {
                            if release_tenant(&mut tenants.ids, &mut tenants.refs, &mut admit, old)
                            {
                                reclaim_arena(&mut hws, &mut bufs, old);
                            }
                        }
                    }
                    admit.set_qos(id, QosClass { weight: weight.max(1), max_inflight });
                    cluster.set_tenant_weight(id, weight);
                    round_due = round_due || admit.has_eligible();
                    reply.send(ok(vec![
                        ("tenant", i(id as i64)),
                        ("name", s(tenant)),
                        ("weight", i(weight.max(1) as i64)),
                    ]));
                }
                Msg::Resume { reply } => {
                    paused = false;
                    round_due = cluster.has_pending() || admit.has_eligible();
                    reply.send(ok(vec![]));
                }
                Msg::SetPolicy { user, name, reply } => {
                    let slot = user_slot(&mut user_index, &mut free_slots, &mut next_fresh, user);
                    let r = if cluster.set_user_policy(slot, &name) {
                        round_due = cluster.has_pending() || admit.has_eligible();
                        ok(vec![("policy", s(name))])
                    } else {
                        err_val(&format!("unknown policy {name:?}"))
                    };
                    reply.send(r);
                }
                Msg::Submit { user, jobs, wait, reply } => {
                    let slot = user_slot(&mut user_index, &mut free_slots, &mut next_fresh, user);
                    let tenant = tenants.of_conn(user);
                    // Fail fast on unknown names: the whole batch is
                    // refused before anything is queued.
                    if let Some(e) = jobs
                        .iter()
                        .find_map(|j| cluster.core(0).validate(&j.accname, None).err())
                    {
                        reply.send(err_val(&e));
                        continue;
                    }
                    // The submission trust boundary: resolve every
                    // operand handle against the caller's tenant NOW.
                    // A forged, stale or foreign handle refuses the
                    // whole batch with a structured reply before
                    // anything is queued; past this point jobs carry
                    // raw physical addresses and are never re-checked.
                    let mut resolved: Vec<ExecJob> = Vec::with_capacity(jobs.len());
                    let mut bad: Option<Value> = None;
                    'resolve: for job in &jobs {
                        let mut params = Vec::with_capacity(job.params.len());
                        for (name, h) in &job.params {
                            match bufs.resolve(*h, tenant) {
                                Ok((addr, _)) => params.push((name.clone(), addr)),
                                Err(e) => {
                                    bad = Some(e.into_value());
                                    break 'resolve;
                                }
                            }
                        }
                        resolved.push(ExecJob {
                            accname: job.accname.clone(),
                            params,
                            tiles: job.tiles,
                        });
                    }
                    if let Some(v) = bad {
                        reply.send(v);
                        continue;
                    }
                    let jobs = resolved;
                    // Backpressure applies to ASYNC submissions, which
                    // a client can pile up without bound.  A blocking
                    // `run` batch is exempt — the connection blocks on
                    // it, so it holds at most one, and the connection
                    // cap already bounds that state (pre-pipeline
                    // behaviour, kept for compatibility).
                    if !wait {
                        // A batch that could NEVER fit the bounded
                        // queue is a terminal error, not a Busy:
                        // retrying would livelock the client forever.
                        if jobs.len() > admit.config().queue_cap {
                            reply.send(err_val(&format!(
                                "batch of {} jobs exceeds the admission queue capacity ({})\
                                 ; split the batch",
                                jobs.len(),
                                admit.config().queue_cap
                            )));
                            continue;
                        }
                        // Bounded-queue backpressure: a batch is
                        // accepted or refused atomically, so `Busy`
                        // rejections trivially conserve requests.
                        if admit.free_capacity(tenant) < jobs.len() {
                            stats.busy_rejections.fetch_add(1, Ordering::Relaxed);
                            admit.note_rejected(tenant, jobs.len() as u64);
                            let queued = admit.queued_of(tenant) as u64;
                            reply.send(busy_val(
                                &format!(
                                    "tenant {tenant} admission queue full ({queued} queued)"
                                ),
                                queued + 1,
                            ));
                            continue;
                        }
                        // Bounded ticket store: an async client must
                        // drain its settled tickets before submitting
                        // more.
                        if open_tickets.get(&user).copied().unwrap_or(0) >= MAX_OPEN_TICKETS {
                            stats.busy_rejections.fetch_add(1, Ordering::Relaxed);
                            admit.note_rejected(tenant, jobs.len() as u64);
                            reply.send(busy_val(
                                &format!(
                                    "connection holds {MAX_OPEN_TICKETS} unclaimed tickets\
                                     ; drain them with wait/poll/completions"
                                ),
                                10,
                            ));
                            continue;
                        }
                    }
                    let n = jobs.len();
                    let sink = if wait {
                        BatchSink::Reply(reply)
                    } else {
                        let id = next_ticket;
                        next_ticket += 1;
                        tickets.insert(id, Ticket { user, done: None, waiters: Vec::new() });
                        *open_tickets.entry(user).or_insert(0) += 1;
                        stats.async_submits.fetch_add(1, Ordering::Relaxed);
                        reply.send(ok(vec![("ticket", i(id as i64)), ("jobs", i(n as i64))]));
                        BatchSink::Ticket(id)
                    };
                    let batch = Batch {
                        sink,
                        remaining: n,
                        latencies_us: Vec::new(),
                        modelled_us: Vec::new(),
                        error: None,
                    };
                    if n == 0 {
                        // Empty batch: settle now.
                        finish(batch, &mut tickets, &mut open_tickets);
                        continue;
                    }
                    for job in jobs {
                        let token = next_token;
                        next_token += 1;
                        // Capacity pre-checked (async) or exempt
                        // (blocking), so this cannot refuse.
                        admit.enqueue_forced(AdmitRequest {
                            user: slot,
                            tenant,
                            job: token,
                            accel: job.accname.clone(),
                            tiles: job.tiles,
                            pin: None,
                        });
                        pending.insert(token, PendingJob::new(job, next_batch));
                    }
                    batches.insert(next_batch, batch);
                    next_batch += 1;
                    round_due = true;
                    // First work arrived: arm the fault plan's outage
                    // sentinels (virtual time is still at the point the
                    // simulator calls t=0, so `at_ns` lines up).  A
                    // sentinel already due — an outage at virtual 0 —
                    // is applied NOW, before the scheduling round this
                    // submission triggers: the simulator processes a
                    // t=0 BoardDown in the arrival batch, ahead of the
                    // first ingest, and the daemon must match it.
                    for (t, b, kind) in fault_events.drain(..) {
                        if t <= vnow {
                            match kind {
                                DOWN_ANCHOR => handle_board_down(
                                    &mut cluster,
                                    &mut hws,
                                    &mut inflight,
                                    &mut pending,
                                    &mut parked_snaps,
                                    b,
                                    vnow,
                                ),
                                REVIVE_ANCHOR => cluster.revive_board(b),
                                _ => {}
                            }
                        } else {
                            completions.push(Reverse((t, seq, b, kind)));
                            seq += 1;
                        }
                    }
                }
                Msg::DrainBoard { board, reply } => {
                    let v = if board < cluster.len() {
                        cluster.drain_board(board);
                        ok(vec![
                            ("board", i(board as i64)),
                            ("health", s(cluster.health(board).name())),
                        ])
                    } else {
                        err_val(&format!("no board {board} (cluster has {})", cluster.len()))
                    };
                    reply.send(v);
                }
                Msg::ReviveBoard { board, reply } => {
                    let v = if board < cluster.len() {
                        cluster.revive_board(board);
                        round_due = cluster.has_pending() || admit.has_eligible();
                        ok(vec![
                            ("board", i(board as i64)),
                            ("health", s(cluster.health(board).name())),
                        ])
                    } else {
                        err_val(&format!("no board {board} (cluster has {})", cluster.len()))
                    };
                    reply.send(v);
                }
                _ => unreachable!("handle_cheap services every other message"),
            }
            continue; // drain every queued message before dispatching
        }
        if paused {
            continue;
        }

        if !round_due {
            // Advance the virtual clock to the next completion(s); the
            // freed modules stay resident for reuse, and the newly
            // idle capacity warrants a fresh round.  Execution happens
            // HERE (deferred from dispatch): entries missing from
            // `inflight` were preempted mid-span (or are ticks) and
            // only advance the clock — the simulator's exact rule.
            if let Some(&Reverse((t, _, _, _))) = completions.peek() {
                vnow = t;
                let mut fault_round = false;
                // Collect the whole equal-timestamp batch before
                // processing (the simulator's batching rule made
                // explicit), then apply the ordering-fuzz hook.  Safe:
                // no handler below pushes back into `completions` at
                // the current timestamp (scenario retries land ≥ 1ms
                // out), so the batch is complete when permuted — and
                // identity permutation keeps pop order byte-identical.
                let mut batch: Vec<(u64, usize, usize)> = Vec::new();
                while let Some(&Reverse((t2, _, _, _))) = completions.peek() {
                    if t2 != t {
                        break;
                    }
                    let Reverse((_, sq, ev_board, anchor)) = completions.pop().unwrap();
                    batch.push((sq, ev_board, anchor));
                }
                order.permute_events(t, &mut batch);
                for (sq, ev_board, anchor) in batch {
                    match anchor {
                        // A scenario-trace arrival: enqueue the job's
                        // requests into admission exactly as the
                        // simulator's `pipeline_enqueue` does, honouring
                        // `Busy` backpressure with a re-arrival sentinel
                        // at the hint's deadline.
                        ARRIVAL_ANCHOR => {
                            let w = replay.as_ref().expect("arrival sentinel without scenario");
                            let (j, count) = replay_events[ev_board];
                            let spec = &w.jobs[j];
                            for k in 0..count {
                                let r = AdmitRequest {
                                    user: spec.user,
                                    tenant: spec.user,
                                    job: next_token,
                                    accel: spec.accel.clone(),
                                    tiles: spec.tiles_per_request,
                                    pin: spec.pin_variant.clone(),
                                };
                                if let Err(e) = admit.enqueue(r) {
                                    replay_events.push((j, count - k));
                                    completions.push(Reverse((
                                        vnow + e.retry_after_ns(),
                                        seq,
                                        replay_events.len() - 1,
                                        ARRIVAL_ANCHOR,
                                    )));
                                    seq += 1;
                                    break;
                                }
                                pending.insert(
                                    next_token,
                                    PendingJob::new(
                                        ExecJob {
                                            accname: spec.accel.clone(),
                                            params: Vec::new(),
                                            tiles: spec.tiles_per_request,
                                        },
                                        scenario_batch,
                                    ),
                                );
                                next_token += 1;
                            }
                            fault_round = true;
                            continue;
                        }
                        // Injected board failure: drain + migrate — the
                        // simulator's BoardDown event, verbatim.
                        DOWN_ANCHOR => {
                            handle_board_down(
                                &mut cluster,
                                &mut hws,
                                &mut inflight,
                                &mut pending,
                                &mut parked_snaps,
                                ev_board,
                                vnow,
                            );
                            fault_round = true;
                            continue;
                        }
                        REVIVE_ANCHOR => {
                            cluster.revive_board(ev_board);
                            fault_round = true;
                            continue;
                        }
                        // Backoff expiry: only wakes the loop; the
                        // release itself happens in the round section.
                        RETRY_ANCHOR => {
                            fault_round = true;
                            continue;
                        }
                        _ => {}
                    }
                    if let Some(inf) = inflight.remove(&sq) {
                        let b = inf.board;
                        // Injected transient run error — consumed per
                        // non-cancelled completion, in completion
                        // order, exactly as the simulator does: the
                        // dispatch's work is lost and the request
                        // re-queued for a clean re-run.
                        if plan.as_mut().is_some_and(|p| p.run_should_fail(b))
                            && cluster.fail_run(b, anchor, vnow)
                        {
                            if hws[b].running_seq.get(&anchor) == Some(&sq) {
                                hws[b].running_seq.remove(&anchor);
                            }
                            // A failed Resume consumed its snapshot.
                            if inf.d.kind == DecisionKind::Resume {
                                if let Some(id) = inf.d.ckpt {
                                    hws[b].snapshots.remove(&id);
                                }
                            }
                            pending.insert(
                                inf.d.job,
                                PendingJob {
                                    job: inf.job,
                                    batch: inf.batch,
                                    carry_us: inf.carry_us,
                                    // The failed slice's virtual time
                                    // was genuinely consumed.
                                    carry_modelled_us: inf.carry_modelled_us
                                        + inf.lat_ns as f64 / 1e3,
                                    failed: inf.err,
                                },
                            );
                            continue;
                        }
                        if hws[b].running_seq.get(&anchor) == Some(&sq) {
                            hws[b].running_seq.remove(&anchor);
                        }
                        cluster.complete(b, anchor);
                        // Return the tenant's in-flight token exactly
                        // once per admitted request (a preempted Run
                        // never gets here — its Resume does).
                        admit.complete(inf.d.tenant);
                        finish_inflight(
                            &mut hws,
                            &mut batches,
                            &mut tickets,
                            &mut open_tickets,
                            &symbols,
                            inf,
                        );
                    }
                }
                round_due = fault_round || cluster.has_pending() || admit.has_eligible();
            }
            continue;
        }
        round_due = false;

        // Release backoff-expired retries (and revival-parked work)
        // before admitting new arrivals — the simulator's exact order —
        // mirroring any checkpoint adoptions in the per-board snapshot
        // stores.
        let released = cluster.release_retries(vnow);
        move_snapshots(&mut hws, &mut parked_snaps, &released.moved_ckpts);

        // Batched ingest: one admission round hands every eligible
        // queued request (weighted DRR under token-bucket quotas) to
        // the scheduler — board routing happens here, in ingest order,
        // exactly as in the simulator.  With every board down, ingest
        // waits: queued work stays in the admission pipeline until a
        // revival re-opens routing.
        if cluster.healthy_count() > 0 {
            for r in admit.ingest_ordered(&order, vnow) {
                match cluster
                    .submit_for(r.user, r.tenant, r.job, &r.accel, r.tiles, r.pin.as_deref())
                {
                    Ok(_board) => {
                        stats.admitted.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        // Admission was validated at enqueue, so this
                        // is a catalog swap mid-flight: fail the job,
                        // return the token.
                        admit.complete(r.tenant);
                        if let Some(p) = pending.remove(&r.job) {
                            fail_job(&mut batches, &mut tickets, &mut open_tickets, p.batch, e);
                        }
                    }
                }
            }
        }

        // One scheduling round per board at the current virtual time,
        // in board order (the cluster simulator's exact rule): an idle
        // board first steals from the deepest over-threshold backlog,
        // then places as many requests as its policy allows.
        // Reconfigurations are mirrored onto the hardware immediately;
        // compute is deferred to the decision's virtual completion (or
        // preemption point).
        let mut placed = false;
        let mut stopping = false;
        'rounds: for b in 0..n_boards {
            cluster.steal_into(b);
            cluster.begin_round_at(b, vnow);
            loop {
                let t_sched = Instant::now();
                let Some(d) = cluster.next_decision(b) else { break };
                // Only committed decisions count toward the Table-4
                // mean — the terminal empty scan would skew it.
                stats
                    .sched_ns
                    .fetch_add(t_sched.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.sched_decisions.fetch_add(1, Ordering::Relaxed);
                // Publish the counters before any client can observe
                // this decision's batch reply — readers must never see
                // pre-decision totals.
                mirror_counters(&stats, &cluster);
                placed = true;

                if d.kind == DecisionKind::Preempt {
                    // Cancel the victim's virtual completion, run the
                    // slice the virtual clock says finished, checkpoint
                    // the accelerator, and re-link the proto job so the
                    // later Resume decision finds its context again —
                    // checkpoint_slice is shared with the board-down
                    // drain, so the two paths cannot drift.
                    let hw = &mut hws[b];
                    if let Some(vseq) = hw.running_seq.remove(&d.anchor) {
                        if let Some(inf) = inflight.remove(&vseq) {
                            let done = inf.d.tiles - d.tiles;
                            let (snap, carry, failed) = checkpoint_slice(hw, &inf, done, true);
                            if let Some(snap) = snap {
                                hw.snapshots
                                    .insert(d.ckpt.expect("preempt without ckpt id"), snap);
                            }
                            let carry_modelled_us = inf.carry_modelled_us
                                + vnow.saturating_sub(inf.start_ns) as f64 / 1e3;
                            pending.insert(
                                d.job,
                                PendingJob {
                                    job: inf.job,
                                    batch: inf.batch,
                                    carry_us: inf.carry_us + carry,
                                    carry_modelled_us,
                                    failed,
                                },
                            );
                        }
                    }
                    continue;
                }

                // Injected reconfiguration fault — the plan is drawn
                // for every reconfiguring dispatch, in dispatch order,
                // exactly as the simulator does; a failure skips the
                // hardware (the load never happens) and the request is
                // parked for a backoff retry or rejected at the cap.
                // Its pending entry stays: the retried dispatch (or
                // the rejected sweep) keeps the job token.
                if d.reconfigure && plan.as_mut().is_some_and(|p| p.reconfig_should_fail(b)) {
                    if let Some(FailDisposition::Retry { at_ns }) =
                        cluster.reconfig_outcome(b, &d, true, vnow)
                    {
                        completions.push(Reverse((at_ns, seq, b, RETRY_ANCHOR)));
                        seq += 1;
                    }
                    continue;
                }

                // Virtual service latency from this shard's cost model
                // — identical to the simulator's for the same decision.
                let busy_others = cluster.busy_anchors(b).saturating_sub(1);
                let lat = cluster.service_ns(b, &d, busy_others);
                cluster.mark_running(b, &d, vnow, vnow + lat);

                let p = pending.remove(&d.job).expect("decision for unknown job token");
                let mut handle = None;
                let mut err = p.failed.clone();
                let mut load_failed = false;
                // Mirror the configuration effect even when an earlier
                // slice already failed (err pre-set): the shard's
                // region map has recorded this placement either way,
                // and skipping the load would leave the hardware's
                // residency permanently diverged at this anchor.  Only
                // compute is gated on `err`.
                {
                    let hw = &mut hws[b];
                    match ensure_module(&mut hw.cynq, &mut hw.resident, &symbols, &d) {
                        Ok(h) => handle = Some(h),
                        Err(fail) => {
                            if fail.module_missing && d.reconfigure {
                                // A real CynqError from
                                // load_accelerator_at: recovered below
                                // through the same retry/reject path as
                                // an injected ReconfigFail.
                                load_failed = true;
                            } else {
                                if fail.module_missing {
                                    // Reuse at an unresident anchor:
                                    // forget the phantom residency so
                                    // the next decision reconfigures.
                                    cluster.evict(b, d.anchor);
                                }
                                if err.is_none() {
                                    err = Some(fail.msg);
                                }
                            }
                        }
                    }
                }
                if d.reconfigure {
                    // Report the hardware outcome: success resets the
                    // accelerator's failure streak; a real load failure
                    // rolls the placement back (running record
                    // included) and parks the request for an
                    // exponential-backoff retry — or surfaces a
                    // structured rejection once the per-accel cap is
                    // spent.
                    if let Some(disp) = cluster.reconfig_outcome(b, &d, load_failed, vnow) {
                        if let FailDisposition::Retry { at_ns } = disp {
                            completions.push(Reverse((at_ns, seq, b, RETRY_ANCHOR)));
                            seq += 1;
                        }
                        pending.insert(d.job, p);
                        continue;
                    }
                }
                if d.kind == DecisionKind::Run {
                    stats.jobs.fetch_add(1, Ordering::Relaxed);
                }
                if d.replicated {
                    stats.replicated_jobs.fetch_add(1, Ordering::Relaxed);
                }
                completions.push(Reverse((vnow + lat, seq, b, d.anchor)));
                hws[b].running_seq.insert(d.anchor, seq);
                inflight.insert(
                    seq,
                    Inflight {
                        board: b,
                        job: p.job,
                        batch: p.batch,
                        handle,
                        err,
                        start_ns: vnow,
                        lat_ns: lat,
                        carry_us: p.carry_us,
                        carry_modelled_us: p.carry_modelled_us,
                        d,
                    },
                );
                seq += 1;

                // Keep cheap RPCs (connects, mem ops, stats) responsive
                // between decisions. State-changing messages are
                // deferred to the inbox so arrivals keep the
                // simulator's between-rounds cadence
                // (decision-sequence parity).
                while let Ok(m) = rx.try_recv() {
                    match handle_cheap(
                        m,
                        &mut hws,
                        &cluster,
                        &admit,
                        &mut tickets,
                        &mut open_tickets,
                        &mut paused,
                        &mut user_index,
                        &mut free_slots,
                        &mut next_fresh,
                        &mut tenants,
                        &mut bufs,
                        &auth,
                        &mut ctl,
                        &symbols,
                    ) {
                        None => {}
                        Some(Msg::Stop) => {
                            stopping = true;
                            break;
                        }
                        Some(other) => inbox.push_back(other),
                    }
                }
                if stopping || paused {
                    break 'rounds; // hold the rest of the rounds
                }
            }

            // Per-board preemption-check cadence — the core-owned rule
            // the simulator uses verbatim, so the two paths cannot
            // drift apart on when a re-check round happens (that would
            // break decision parity).
            let due = cluster.preempt_tick_due(b, &mut hws[b].next_tick, vnow);
            if let Some(t) = due {
                // Jitter moves only the heap entry; `next_tick` keeps
                // the unjittered due time (simulator rule, verbatim).
                completions.push(Reverse((order.jitter_tick(b, t), seq, b, TICK_ANCHOR)));
                seq += 1;
            }
        }
        // Mirror the counters once more: the terminal next_decision()
        // scans may have deferred users (skips).
        mirror_counters(&stats, &cluster);

        // Requests any shard rejected instead of dispatching (unknown
        // accelerator past admission, or a policy naming an unknown
        // variant): surface the reason to the waiting client — the
        // dispatcher itself stays alive.  Swept here (not per board
        // inside the round loop) so a paused/stopping early break can
        // never strand a rejection.
        for b in 0..n_boards {
            for (req, reason) in cluster.take_rejected(b) {
                admit.complete(req.tenant);
                if let Some(id) = req.resume {
                    hws[b].snapshots.remove(&id);
                }
                if let Some(p) = pending.remove(&req.job) {
                    fail_job(&mut batches, &mut tickets, &mut open_tickets, p.batch, reason);
                }
            }
        }

        if stopping {
            break 'outer;
        }

        if !placed && !paused && inflight.is_empty() && cluster.has_pending() {
            // Stall guard: nothing running anywhere, nothing placeable,
            // so no future completion can unblock these requests —
            // fail them instead of hanging their clients.
            for (b, req) in cluster.drain_pending() {
                let policy_name = cluster.policy_name_of(req.user);
                admit.complete(req.tenant);
                if let Some(id) = req.resume {
                    hws[b].snapshots.remove(&id);
                }
                if let Some(p) = pending.remove(&req.job) {
                    fail_job(
                        &mut batches,
                        &mut tickets,
                        &mut open_tickets,
                        p.batch,
                        format!(
                            "request for {:?} is unplaceable under policy {policy_name:?}",
                            symbols.resolve(req.accel)
                        ),
                    );
                }
            }
            // The returned tokens may make more queued work eligible —
            // ingest it next iteration (it may drain the same way).
            round_due = admit.has_eligible();
        }
    }
}

/// Consume a Resume dispatch's pending register-file snapshot and,
/// when its module is live, restore it.  Shared by normal completion
/// ([`finish_inflight`]) and preempt-of-a-Resume so the two paths
/// cannot drift; consuming unconditionally keeps the snapshot map
/// leak-free even when the dispatch already failed (the snapshot is
/// then just discarded).  `Ok` for non-Resume dispatches.  A failed
/// restore rolls back to an error — the module itself is untouched and
/// stays reusable.
fn take_and_restore_snapshot(
    cynq: &mut Cynq,
    snapshots: &mut HashMap<u64, AccelSnapshot>,
    inf: &Inflight,
) -> Result<(), String> {
    if inf.d.kind != DecisionKind::Resume {
        return Ok(());
    }
    let id = inf.d.ckpt.expect("resume without checkpoint id");
    let snap = snapshots
        .remove(&id)
        .ok_or_else(|| format!("internal: checkpoint {id} has no snapshot"))?;
    match inf.handle {
        Some(h) => cynq.restore_accelerator(h, &snap).map_err(|e| e.to_string()),
        // The (re)load already failed (error recorded at dispatch);
        // the snapshot is discarded with it.
        None => Ok(()),
    }
}

/// Copy a completed job's output buffers from the board that computed
/// them back into the primary (board 0) arena clients read from — the
/// cluster's explicit cross-board result transfer.  Inputs need no
/// staging: [`mem_op`] broadcasts every write, so operands are already
/// resident on all boards at the same addresses.  No-op on board 0.
fn sync_outputs_to_primary(
    hws: &mut [BoardHw],
    board: usize,
    job: &ExecJob,
    accel: &str,
    owner: TenantId,
) -> Result<(), String> {
    if board == 0 {
        return Ok(());
    }
    let Some(spec) = hws[0].cynq.catalog.get(accel).cloned() else {
        return Ok(()); // decisions never name unknown accelerators
    };
    let n_in = spec.inputs.len();
    // Non-control registers zip with input specs then output specs —
    // the same ordering `Cynq::run` DMAs by.
    for (k, reg) in spec.registers.iter().filter(|r| r.name != "control").enumerate() {
        if k < n_in {
            continue;
        }
        let Some(out) = spec.outputs.get(k - n_in) else { break };
        let Some(&(_, addr)) = job.params.iter().find(|(name, _)| name == &reg.name) else {
            continue; // job did not program this output register
        };
        let data = hws[board]
            .cynq
            .read_f32_for(owner, PhysAddr(addr), out.bytes() / 4)
            .map_err(|e| e.to_string())?;
        hws[0].cynq.write_f32_for(owner, PhysAddr(addr), &data).map_err(|e| e.to_string())?;
    }
    Ok(())
}

/// Execute a dispatch at its virtual completion: restore the checkpoint
/// for resumes, program the operand registers, run every tile, sync the
/// outputs back to the primary arena, and settle the batch reply.
/// Errors recorded at dispatch (failed loads) surface here too.
fn finish_inflight(
    hws: &mut [BoardHw],
    batches: &mut HashMap<usize, Batch>,
    tickets: &mut HashMap<u64, Ticket>,
    open_tickets: &mut HashMap<u64, usize>,
    symbols: &SymbolTable,
    inf: Inflight,
) {
    let board = inf.board;
    let mut err = inf.err;
    let t0 = Instant::now();
    // A Resume consumes its snapshot however it ends — a checkpoint
    // whose resume errored must not sit in the map forever.
    let restored = {
        let hw = &mut hws[board];
        take_and_restore_snapshot(&mut hw.cynq, &mut hw.snapshots, &inf)
    };
    if err.is_none() {
        let h = inf.handle.expect("loaded dispatch without handle");
        let owner = owner_of(inf.d.tenant);
        let r = restored
            .and_then(|()| {
                let hw = &mut hws[board];
                run_tiles(&mut hw.cynq, h, &inf.job, inf.d.tiles, owner)
            })
            .and_then(|()| {
                sync_outputs_to_primary(hws, board, &inf.job, symbols.resolve(inf.d.accel), owner)
            });
        if let Err(e) = r {
            err = Some(e);
        }
    }
    let b = batches.get_mut(&inf.batch).expect("decision for unknown batch");
    match err {
        None => {
            b.latencies_us.push(inf.carry_us + t0.elapsed().as_secs_f64() * 1e6);
            b.modelled_us.push(inf.carry_modelled_us + inf.lat_ns as f64 / 1e3);
        }
        Some(e) => b.error = Some(e),
    }
    b.remaining -= 1;
    if b.remaining == 0 {
        let b = batches.remove(&inf.batch).unwrap();
        finish(b, tickets, open_tickets);
    }
}

/// Mirror cluster-level checkpoint moves in the per-board register-file
/// snapshot stores: `from: Some((board, id))` entries move between
/// board stores, `from: None` entries come out of the job-keyed
/// parked-snapshot stash (drained while no board was healthy).
fn move_snapshots(
    hws: &mut [BoardHw],
    parked_snaps: &mut HashMap<u64, AccelSnapshot>,
    moved: &[MovedCkpt],
) {
    for m in moved {
        let snap = match m.from {
            Some((from_board, old)) => hws[from_board].snapshots.remove(&old),
            None => parked_snaps.remove(&m.job),
        };
        if let Some(s) = snap {
            hws[m.to].snapshots.insert(m.new_ckpt, s);
        }
    }
}

/// Run the completed slice of a cancelled dispatch and (optionally)
/// capture a fresh register-file snapshot — the emergency-checkpoint
/// protocol shared by the Preempt branch and the board-failover drain,
/// implemented once so the two paths cannot drift.  A Resume
/// dispatch's own pending snapshot is consumed (and applied) first,
/// whatever else happens.  Returns the snapshot (when requested and
/// nothing failed), the real µs the slice consumed, and the failure to
/// carry into the re-linked job.
fn checkpoint_slice(
    hw: &mut BoardHw,
    inf: &Inflight,
    done: usize,
    snapshot: bool,
) -> (Option<AccelSnapshot>, f64, Option<String>) {
    let restored = take_and_restore_snapshot(&mut hw.cynq, &mut hw.snapshots, inf);
    if let Some(e) = inf.err.clone() {
        return (None, 0.0, Some(e));
    }
    let h = inf.handle.expect("loaded dispatch without handle");
    let t0 = Instant::now();
    let r = restored
        .and_then(|()| run_tiles(&mut hw.cynq, h, &inf.job, done, owner_of(inf.d.tenant)))
        .and_then(|()| {
            if snapshot {
                hw.cynq.checkpoint_accelerator(h).map(Some).map_err(|e| e.to_string())
            } else {
                Ok(None)
            }
        });
    let carry_us = t0.elapsed().as_secs_f64() * 1e6;
    match r {
        Ok(snap) => (snap, carry_us, None),
        Err(e) => (None, carry_us, Some(e)),
    }
}

/// The daemon half of a board failure: drive the cluster-core failover
/// ([`ClusterCore::mark_board_down`]) and mirror it onto the hardware
/// state — every running dispatch's completion is cancelled (its heap
/// entry becomes a clock-advance no-op), the slice the virtual clock
/// says completed is executed for real and checkpointed
/// ([`checkpoint_slice`]), the snapshot moves to the board that
/// adopted the remainder, queued remainders' snapshots move with
/// their checkpoints, and the failed board's fabric is blanked.
#[allow(clippy::too_many_arguments)]
fn handle_board_down(
    cluster: &mut ClusterCore,
    hws: &mut [BoardHw],
    inflight: &mut HashMap<u64, Inflight>,
    pending: &mut HashMap<u64, PendingJob>,
    parked_snaps: &mut HashMap<u64, AccelSnapshot>,
    b: usize,
    now: u64,
) {
    if b >= hws.len() {
        return;
    }
    let report = cluster.mark_board_down(b, now);
    for dr in &report.drained {
        let Some(vseq) = hws[b].running_seq.remove(&dr.anchor) else { continue };
        let Some(inf) = inflight.remove(&vseq) else { continue };
        let (snap, carry, failed) = checkpoint_slice(&mut hws[b], &inf, dr.done, dr.done > 0);
        if let Some(snap) = snap {
            match (dr.to, dr.new_ckpt) {
                (Some(to), Some(id)) => {
                    hws[to].snapshots.insert(id, snap);
                }
                // No healthy board yet: park keyed by job until a
                // release reports the adoption.
                _ => {
                    parked_snaps.insert(dr.job, snap);
                }
            }
        }
        let carry_modelled_us =
            inf.carry_modelled_us + now.saturating_sub(inf.start_ns) as f64 / 1e3;
        pending.insert(
            dr.job,
            PendingJob {
                job: inf.job,
                batch: inf.batch,
                carry_us: inf.carry_us + carry,
                carry_modelled_us,
                failed,
            },
        );
    }
    move_snapshots(hws, parked_snaps, &report.moved_ckpts);
    // The board comes back blank: unload every resident module and
    // forget its dispatch state.
    let hw = &mut hws[b];
    let stale: Vec<usize> = hw.resident.keys().copied().collect();
    for a in stale {
        if let Some((h, _)) = hw.resident.remove(&a) {
            let _ = hw.cynq.unload(h);
        }
    }
    hw.running_seq.clear();
    hw.next_tick = None;
}

/// Publish every shard's [`crate::sched::SchedCounters`] into the
/// daemon's atomics —
/// the per-board mirrors plus the cluster-wide totals the legacy
/// fields carry.  The single scheduling-counter source both paths
/// report from.
fn mirror_counters(stats: &DaemonStats, cluster: &ClusterCore) {
    for b in 0..cluster.len() {
        let c = cluster.core(b).counters();
        if let Some(pb) = stats.per_board.get(b) {
            pb.reconfigs.store(c.reconfigs, Ordering::Relaxed);
            pb.reuses.store(c.reuses, Ordering::Relaxed);
            pb.skips.store(c.skips, Ordering::Relaxed);
            pb.replications.store(c.replications, Ordering::Relaxed);
            pb.preemptions.store(c.preemptions, Ordering::Relaxed);
            pb.resumes.store(c.resumes, Ordering::Relaxed);
        }
    }
    let total = cluster.total_counters();
    stats.reconfig_loads.store(total.reconfigs, Ordering::Relaxed);
    stats.reuse_hits.store(total.reuses, Ordering::Relaxed);
    stats.skips.store(total.skips, Ordering::Relaxed);
    stats.replications.store(total.replications, Ordering::Relaxed);
    stats.preemptions.store(total.preemptions, Ordering::Relaxed);
    stats.resumes.store(total.resumes, Ordering::Relaxed);
    let cc = cluster.cluster_counters();
    stats.routed.store(cc.routed, Ordering::Relaxed);
    stats.steals.store(cc.steals, Ordering::Relaxed);
    stats.failovers.store(cc.failovers, Ordering::Relaxed);
    stats.migrations.store(cc.migrations, Ordering::Relaxed);
    stats.lost_ns.store(cc.lost_ns, Ordering::Relaxed);
    stats.reconfig_failures.store(cc.reconfig_failures, Ordering::Relaxed);
    stats.reconfig_retries.store(cc.reconfig_retries, Ordering::Relaxed);
    stats.reconfig_rejections.store(cc.reconfig_rejections, Ordering::Relaxed);
    stats.run_faults.store(cc.run_faults, Ordering::Relaxed);
}

/// Answer a message that needs no scheduling-state change (mem ops,
/// connection Hello, stats/log queries, ticket wait/poll/drain, pause)
/// — callable both from the top-level drain and mid-round, so long
/// rounds don't head-of-line block cheap RPCs. Returns the message
/// back when it *does* change scheduling state (Submit, Session,
/// SetPolicy, Resume, Goodbye, Stop) for the caller to process at
/// round boundaries.
#[allow(clippy::too_many_arguments)]
fn handle_cheap(
    msg: Msg,
    hws: &mut [BoardHw],
    cluster: &ClusterCore,
    admit: &AdmissionPipeline,
    tickets: &mut HashMap<u64, Ticket>,
    open_tickets: &mut HashMap<u64, usize>,
    paused: &mut bool,
    user_index: &mut HashMap<u64, usize>,
    free_slots: &mut std::collections::BTreeSet<usize>,
    next_fresh: &mut usize,
    tenants: &mut TenantDirectory,
    bufs: &mut BufTable,
    auth: &Option<Arc<Mutex<AuthState>>>,
    ctl: &mut CtlGovernor,
    symbols: &SymbolTable,
) -> Option<Msg> {
    match msg {
        Msg::Mem { user, op, reply } => {
            let tenant = tenants.of_conn(user);
            reply.send(mem_op(hws, bufs, tenant, op));
        }
        Msg::Hello { user, proto, reply } => {
            let slot = user_slot(user_index, free_slots, next_fresh, user);
            let mut fields = vec![("user", i(user as i64)), ("slot", i(slot as i64))];
            // v2 handshake: echo the negotiated version (absent for
            // the legacy `ping`, whose reply shape is frozen).
            if let Some(p) = proto {
                fields.push(("proto", i(i64::from(p))));
            }
            reply.send(ok(fields));
        }
        Msg::RegisterTenant { user, admin_token, name, reply } => {
            let v = match auth {
                // Open mode has no admin token, so nothing can gate
                // minting — refuse rather than hand out tokens that
                // the `session` bind would never check.
                None => err_val("register-tenant requires an authenticated daemon (--tenants)"),
                Some(a) => {
                    let mut a = a.lock().unwrap();
                    if admin_token != a.admin {
                        // Shares the per-connection failed-auth bucket
                        // with `session` binds: admin-token guessing is
                        // still auth guessing.
                        match ctl.charge_auth_fail(user) {
                            Ok(()) => denied_val("register-tenant denied: bad admin token"),
                            Err(ms) => busy_val("too many failed auth attempts", ms),
                        }
                    } else {
                        let tok = a.mint();
                        a.tokens.insert(name.clone(), tok.clone());
                        ok(vec![("name", s(name)), ("token", s(tok))])
                    }
                }
            };
            reply.send(v);
        }
        Msg::Audit { user, limit, reply } => {
            // Every audit read is charged: the log walk below is the
            // control plane's most expensive read and must not become
            // a per-connection busy loop.
            if let Err(ms) = ctl.charge_audit(user) {
                reply.send(busy_val("audit rate limit exceeded", ms));
                return None;
            }
            // Per-tenant filtered view of the merged decision log: a
            // tenant sees its own placements (board, anchor, kind,
            // timing inputs) and nothing of its neighbours'.
            let tenant = tenants.of_conn(user);
            let filtered: Vec<(usize, Decision)> = cluster
                .merged_log()
                .copied()
                .filter(|(_, d)| d.tenant == tenant)
                .collect();
            let skip = filtered.len().saturating_sub(limit.unwrap_or(usize::MAX));
            let items: Vec<Value> =
                filtered[skip..].iter().map(|(b, d)| decision_value(symbols, *b, d)).collect();
            reply.send(ok(vec![
                ("tenant", i(tenant as i64)),
                ("decisions", arr(items)),
            ]));
        }
        Msg::Wait { user, ticket, reply } => {
            if tickets.get(&ticket).map(|t| t.user) != Some(user) {
                reply.send(err_val(&format!("unknown ticket {ticket}")));
            } else if tickets.get(&ticket).is_some_and(|t| t.done.is_some()) {
                let t = tickets.remove(&ticket).expect("checked above");
                close_ticket(open_tickets, t.user);
                reply.send(t.done.expect("checked above"));
            } else {
                // Settled later by `finish` (which consumes the ticket).
                tickets
                    .get_mut(&ticket)
                    .expect("checked above")
                    .waiters
                    .push(reply);
            }
        }
        Msg::Poll { user, ticket, reply } => {
            let v = match tickets.get(&ticket) {
                Some(t) if t.user == user => match &t.done {
                    Some(resp) => ok(vec![("done", i(1)), ("result", resp.clone())]),
                    None => ok(vec![("done", i(0))]),
                },
                _ => err_val(&format!("unknown ticket {ticket}")),
            };
            reply.send(v);
        }
        Msg::Completions { user, reply } => {
            let mut done_ids: Vec<u64> = tickets
                .iter()
                .filter(|(_, t)| t.user == user && t.done.is_some())
                .map(|(&id, _)| id)
                .collect();
            done_ids.sort_unstable();
            let items: Vec<Value> = done_ids
                .into_iter()
                .map(|id| {
                    let t = tickets.remove(&id).unwrap();
                    close_ticket(open_tickets, t.user);
                    obj(vec![
                        ("ticket", i(id as i64)),
                        ("result", t.done.unwrap()),
                    ])
                })
                .collect();
            reply.send(ok(vec![("completions", arr(items))]));
        }
        Msg::Query { reply } => {
            reply.send(stats_value(cluster, admit, *paused));
        }
        Msg::QueryCluster { reply } => {
            reply.send(cluster_stats_value(cluster, *paused));
        }
        Msg::QueryBoard { board, reply } => {
            let v = if board < cluster.len() {
                ok(board_fields(cluster, board))
            } else {
                err_val(&format!("no board {board} (cluster has {})", cluster.len()))
            };
            reply.send(v);
        }
        Msg::QueryLog { board, limit, reply } => {
            // Tail-only POD copies (decisions carry interned symbols,
            // no heap fields), O(1) positioning: a monitoring poll on a
            // long-lived daemon never walks the whole ring under the
            // dispatcher's feet.
            let n = limit.unwrap_or(usize::MAX);
            let out: Vec<Decision> = match board {
                Some(b) if b < cluster.len() => {
                    cluster.core(b).decision_log_tail(n).copied().collect()
                }
                Some(_) => Vec::new(),
                None => cluster.merged_log_tail(n).map(|(_, d)| *d).collect(),
            };
            let _ = reply.send(out);
        }
        Msg::QueryMergedTagged { reply } => {
            let _ = reply.send(cluster.merged_log().copied().collect());
        }
        Msg::Pause { reply } => {
            *paused = true;
            reply.send(ok(vec![]));
        }
        other => return Some(other),
    }
    None
}

/// The `stats` RPC reply: queue depth (admission + scheduler queues),
/// the cluster-wide counter totals, and one object per live tenant
/// (single-board daemons report exactly the shard's counters).
fn stats_value(cluster: &ClusterCore, admit: &AdmissionPipeline, paused: bool) -> Value {
    let c = cluster.total_counters();
    let sched = cluster.tenant_counters();
    let tenants: Vec<Value> = admit
        .tenant_counters()
        .into_iter()
        .map(|(id, tc)| {
            let sc = sched.get(&id).copied().unwrap_or_default();
            obj(vec![
                ("tenant", i(id as i64)),
                ("weight", i(admit.qos(id).weight as i64)),
                ("queued", i(admit.queued_of(id) as i64)),
                ("inflight", i(admit.inflight_of(id) as i64)),
                ("enqueued", i(tc.enqueued as i64)),
                ("admitted", i(tc.admitted as i64)),
                ("completed", i(sc.completed as i64)),
                ("preempted", i(sc.preempted as i64)),
                ("busy_rejected", i(tc.rejected as i64)),
                ("sched_rejected", i(sc.rejected as i64)),
            ])
        })
        .collect();
    ok(vec![
        // Admitted-but-unscheduled plus queued-for-admission: the
        // "work the daemon is holding" number clients poll.
        ("queued", i((cluster.pending() + admit.queued()) as i64)),
        ("admit_queued", i(admit.queued() as i64)),
        ("reconfigs", i(c.reconfigs as i64)),
        ("reuses", i(c.reuses as i64)),
        ("skips", i(c.skips as i64)),
        ("replications", i(c.replications as i64)),
        ("preemptions", i(c.preemptions as i64)),
        ("resumes", i(c.resumes as i64)),
        ("boards", i(cluster.len() as i64)),
        ("paused", i(paused as i64)),
        ("tenants", arr(tenants)),
    ])
}

/// One board's `board-stats` fields: name, queue depth and the
/// shard's scheduling counters.
fn board_fields(cluster: &ClusterCore, b: usize) -> Vec<(&'static str, Value)> {
    let core = cluster.core(b);
    let c = core.counters();
    vec![
        ("board", s(cluster.board(b).name())),
        ("index", i(b as i64)),
        ("health", s(cluster.health(b).name())),
        ("queued", i(core.pending() as i64)),
        ("running", i(core.running_count() as i64)),
        ("reconfigs", i(c.reconfigs as i64)),
        ("reuses", i(c.reuses as i64)),
        ("skips", i(c.skips as i64)),
        ("replications", i(c.replications as i64)),
        ("preemptions", i(c.preemptions as i64)),
        ("resumes", i(c.resumes as i64)),
    ]
}

/// The `cluster-stats` RPC reply: placement policy, routing/stealing
/// counters, totals and one object per board.
fn cluster_stats_value(cluster: &ClusterCore, paused: bool) -> Value {
    let t = cluster.total_counters();
    let cc = cluster.cluster_counters();
    let boards: Vec<Value> = (0..cluster.len()).map(|b| obj(board_fields(cluster, b))).collect();
    ok(vec![
        ("placement", s(cluster.placement_name())),
        ("boards", arr(boards)),
        ("routed", i(cc.routed as i64)),
        ("steals", i(cc.steals as i64)),
        ("queued", i(cluster.pending() as i64)),
        ("reconfigs", i(t.reconfigs as i64)),
        ("reuses", i(t.reuses as i64)),
        ("preemptions", i(t.preemptions as i64)),
        ("resumes", i(t.resumes as i64)),
        // Failure-domain counters (board health is per board above).
        ("healthy", i(cluster.healthy_count() as i64)),
        ("failovers", i(cc.failovers as i64)),
        ("migrations", i(cc.migrations as i64)),
        ("lost_ns", i(cc.lost_ns as i64)),
        ("reconfig_failures", i(cc.reconfig_failures as i64)),
        ("reconfig_retries", i(cc.reconfig_retries as i64)),
        ("reconfig_rejections", i(cc.reconfig_rejections as i64)),
        ("run_faults", i(cc.run_faults as i64)),
        ("parked_retries", i(cluster.parked_count() as i64)),
        ("paused", i(paused as i64)),
    ])
}

/// How a decision's hardware mirror failed. `module_missing` tells the
/// dispatcher whether the core's residency bookkeeping must be rolled
/// back (load never happened) or the module is resident and reusable
/// (compute-only failure).
struct ExecFailure {
    msg: String,
    module_missing: bool,
}

/// Mirror a decision's *configuration* effect onto the hardware at
/// schedule time: evict overlapped modules and (re)load the chosen
/// variant at its anchor, or look up the reused resident instance.
/// Compute is deferred (see [`finish_inflight`] / the preempt branch).
fn ensure_module(
    cynq: &mut Cynq,
    resident: &mut HashMap<usize, (LoadedAccel, usize)>,
    symbols: &SymbolTable,
    d: &Decision,
) -> Result<LoadedAccel, ExecFailure> {
    let missing = |msg: String| ExecFailure { msg, module_missing: true };
    if d.reconfigure {
        // The core already replaced these modules in its bookkeeping;
        // evict every resident module overlapping the new span.
        let stale: Vec<usize> = resident
            .iter()
            .filter(|&(&a, &(_, span))| a < d.anchor + d.span && a + span > d.anchor)
            .map(|(&a, _)| a)
            .collect();
        for a in stale {
            if let Some((h, _)) = resident.remove(&a) {
                cynq.unload(h).map_err(|e| missing(e.to_string()))?;
            }
        }
        let (h, _reconfig_latency) = cynq
            .load_accelerator_at(symbols.resolve(d.accel), symbols.resolve(d.variant), d.anchor)
            .map_err(|e| missing(e.to_string()))?;
        resident.insert(d.anchor, (h, d.span));
        Ok(h)
    } else {
        match resident.get(&d.anchor) {
            Some(&(h, _)) => Ok(h),
            None => Err(missing(format!(
                "internal: reuse at unresident anchor {}",
                d.anchor
            ))),
        }
    }
}

/// Program the job's operand registers and run `tiles` work items in
/// the owning tenant's isolation domain: the DMA engine reads and
/// writes through `*_for` accessors, so even a bad resolved address
/// could never touch a foreign arena.  Failures keep the module
/// resident — it stays reusable.
fn run_tiles(
    cynq: &mut Cynq,
    h: LoadedAccel,
    job: &ExecJob,
    tiles: usize,
    owner: TenantId,
) -> Result<(), String> {
    for (reg, val) in &job.params {
        cynq.write_reg(h, reg, PhysAddr(*val)).map_err(|e| e.to_string())?;
    }
    for _ in 0..tiles {
        cynq.run_as(h, owner).map_err(|e| e.to_string())?;
    }
    Ok(())
}

/// Broadcast a write into every board's DDR arena (operand mirroring:
/// with the allocators in lockstep, a buffer has the same physical
/// address on every board, so a job can be dispatched anywhere without
/// a pre-stage copy).  The write runs in the owning tenant's domain on
/// each board — the arena checks ownership and bounds.
fn write_all(hws: &mut [BoardHw], owner: TenantId, addr: u64, data: &[f32]) -> Result<(), String> {
    for hw in hws.iter_mut() {
        hw.cynq.write_f32_for(owner, PhysAddr(addr), data).map_err(|e| e.to_string())?;
    }
    Ok(())
}

/// Tear down a retired tenant's isolation domain: reclaim its arena on
/// every board (the allocators stay in lockstep — reclaim is
/// per-owner, and owners are cluster-global) and invalidate all of its
/// buffer handles.
fn reclaim_arena(hws: &mut [BoardHw], bufs: &mut BufTable, tenant: usize) {
    let owner = owner_of(tenant);
    for hw in hws.iter_mut() {
        hw.cynq.mem.reclaim_tenant(owner);
    }
    bufs.reclaim_tenant(tenant);
}

/// Serialize one tagged decision for the `audit` RPC.
fn decision_value(symbols: &SymbolTable, board: usize, d: &Decision) -> Value {
    obj(vec![
        ("board", i(board as i64)),
        ("tenant", i(d.tenant as i64)),
        ("user", i(d.user as i64)),
        ("job", i(d.job as i64)),
        ("accel", s(symbols.resolve(d.accel))),
        ("variant", s(symbols.resolve(d.variant))),
        ("anchor", i(d.anchor as i64)),
        ("span", i(d.span as i64)),
        ("tiles", i(d.tiles as i64)),
        ("kind", s(format!("{:?}", d.kind))),
        ("reconfigure", i(d.reconfigure as i64)),
        ("replicated", i(d.replicated as i64)),
    ])
}

/// Apply a memory RPC within the calling tenant's isolation domain.
/// Handles resolve through the [`BufTable`] ownership gate first — a
/// stale/forged handle or a foreign buffer is refused with a
/// structured reply and nothing is touched.  Allocations, frees and
/// writes are mirrored into *every* board's arena — the allocators
/// evolve in lockstep, so addresses agree cluster-wide; reads come
/// from the primary (board 0) arena, into which [`finish_inflight`]
/// syncs every completed job's outputs.
fn mem_op(hws: &mut [BoardHw], bufs: &mut BufTable, tenant: usize, op: MemOp) -> Value {
    let owner = owner_of(tenant);
    match op {
        MemOp::Alloc { bytes } => {
            let mut addr: Option<u64> = None;
            for hw in hws.iter_mut() {
                match hw.cynq.alloc_for(owner, bytes) {
                    Ok(a) => {
                        let expected = *addr.get_or_insert(a.0);
                        if expected != a.0 {
                            return err_val("internal: cluster memory arenas diverged");
                        }
                    }
                    Err(e) => return err_val(&e.to_string()),
                }
            }
            let addr = addr.expect("cluster has at least one board");
            let h = bufs.insert(tenant, addr, bytes);
            ok(vec![("handle", i(h.raw() as i64))])
        }
        MemOp::Free { handle } => {
            let (addr, _) = match bufs.remove(handle, tenant) {
                Ok(x) => x,
                Err(e) => return e.into_value(),
            };
            for hw in hws.iter_mut() {
                if let Err(e) = hw.cynq.free_for(owner, PhysAddr(addr)) {
                    return err_val(&e.to_string());
                }
            }
            ok(vec![])
        }
        MemOp::Write { handle, data } => match bufs.resolve(handle, tenant) {
            Err(e) => e.into_value(),
            Ok((addr, _)) => match write_all(hws, owner, addr, &data) {
                Ok(()) => ok(vec![]),
                Err(e) => err_val(&e),
            },
        },
        MemOp::Read { handle, count } => match bufs.resolve(handle, tenant) {
            Err(e) => e.into_value(),
            Ok((addr, _)) => match hws[0].cynq.read_f32_for(owner, PhysAddr(addr), count) {
                Ok(data) => ok(vec![("b64", s(proto::f32s_to_b64(&data)))]),
                Err(e) => err_val(&e.to_string()),
            },
        },
        MemOp::Import { shm, offset, count, handle } => match bufs.resolve(handle, tenant) {
            Err(e) => e.into_value(),
            Ok((addr, _)) => {
                match SharedMem::open(&shm)
                    .map_err(|e| e.to_string())
                    .and_then(|m| m.read_f32(offset, count).map_err(|e| e.to_string()))
                    .and_then(|data| write_all(hws, owner, addr, &data))
                {
                    Ok(()) => ok(vec![]),
                    Err(e) => err_val(&e),
                }
            }
        },
        MemOp::Export { handle, count, shm, offset } => match bufs.resolve(handle, tenant) {
            Err(e) => e.into_value(),
            Ok((addr, _)) => {
                match hws[0]
                    .cynq
                    .read_f32_for(owner, PhysAddr(addr), count)
                    .map_err(|e| e.to_string())
                    .and_then(|data| {
                        SharedMem::open(&shm).map_err(|e| e.to_string()).and_then(|mut m| {
                            m.write_f32(offset, &data).map_err(|e| e.to_string())
                        })
                    }) {
                    Ok(()) => ok(vec![]),
                    Err(e) => err_val(&e),
                }
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::daemon::FpgaRpc;
    use std::sync::Mutex;

    static LOCK: Mutex<()> = Mutex::new(());

    fn sock(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fos_daemon_{name}_{}.sock", std::process::id()))
    }

    fn start(name: &str) -> (Daemon, PathBuf) {
        let path = sock(name);
        let d = Daemon::start(&path, ShellBoard::Ultra96, Catalog::load_default().unwrap())
            .unwrap();
        (d, path)
    }

    #[test]
    fn single_client_vadd_end_to_end() {
        let _g = LOCK.lock().unwrap();
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let (_d, path) = start("vadd");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let a = rpc.alloc(4 * 4096).unwrap();
        let b = rpc.alloc(4 * 4096).unwrap();
        let c = rpc.alloc(4 * 4096).unwrap();
        let xs: Vec<f32> = (0..4096).map(|i| i as f32).collect();
        let ys: Vec<f32> = (0..4096).map(|i| (i * 2) as f32).collect();
        rpc.write_f32(a, &xs).unwrap();
        rpc.write_f32(b, &ys).unwrap();
        let job = Job::new(
            "vadd",
            vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
        );
        let report = rpc.run(&[job]).unwrap();
        assert_eq!(report.latencies_us.len(), 1);
        assert!(report.modelled_us[0] > 0.0);
        let out = rpc.read_f32(c, 4096).unwrap();
        for k in 0..4096 {
            assert_eq!(out[k], (k * 3) as f32);
        }
    }

    #[test]
    fn two_tenants_interleave_and_share() {
        let _g = LOCK.lock().unwrap();
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let (d, path) = start("multi");
        let mk = |rpc: &mut FpgaRpc,
                  n: usize|
         -> (BufferHandle, BufferHandle, BufferHandle, Vec<Job>) {
            let a = rpc.alloc(4 * 4096).unwrap();
            let b = rpc.alloc(4 * 4096).unwrap();
            let c = rpc.alloc(4 * 4096).unwrap();
            rpc.write_f32(a, &vec![1.0; 4096]).unwrap();
            rpc.write_f32(b, &vec![2.0; 4096]).unwrap();
            let jobs = (0..n)
                .map(|_| {
                    Job::new(
                        "vadd",
                        vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
                    )
                })
                .collect();
            (a, b, c, jobs)
        };
        let path2 = path.clone();
        let t1 = std::thread::spawn(move || {
            let mut rpc = FpgaRpc::connect(&path2).unwrap();
            let (_, _, c, jobs) = mk(&mut rpc, 4);
            rpc.run(&jobs).unwrap();
            rpc.read_f32(c, 4096).unwrap()
        });
        let path3 = path.clone();
        let t2 = std::thread::spawn(move || {
            let mut rpc = FpgaRpc::connect(&path3).unwrap();
            let (_, _, c, jobs) = mk(&mut rpc, 4);
            rpc.run(&jobs).unwrap();
            rpc.read_f32(c, 4096).unwrap()
        });
        let o1 = t1.join().unwrap();
        let o2 = t2.join().unwrap();
        assert!(o1.iter().all(|&v| v == 3.0));
        assert!(o2.iter().all(|&v| v == 3.0));
        // Both users ran the same accelerator: reuse must have happened.
        assert!(d.stats().reuse_hits.load(Ordering::Relaxed) >= 6);
        assert_eq!(d.stats().jobs.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn single_tenant_backlog_replicates_on_live_path() {
        let _g = LOCK.lock().unwrap();
        let (d, path) = start("replicate");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let catalog = Catalog::load_default().unwrap();
        let params = crate::testutil::alloc_operand_params(&mut rpc, &catalog, "mandelbrot");
        // A backlog of long-running requests from ONE tenant: the
        // elastic core must fan them out over the free regions
        // (replication) instead of serialising on one module.
        let jobs: Vec<Job> = (0..4)
            .map(|_| Job::new("mandelbrot", params.clone()).with_tiles(4))
            .collect();
        // Scheduling decisions are made (and logged) even when the
        // compute backend is unavailable, so only gate on the reply.
        if let Ok(report) = rpc.run(&jobs) {
            assert_eq!(report.latencies_us.len(), 4);
        }
        assert!(
            d.stats().replications.load(Ordering::Relaxed) >= 1,
            "expected replication: {:?}",
            d.decision_log()
        );
        assert!(d.stats().replicated_jobs.load(Ordering::Relaxed) >= 1);
        let anchors: std::collections::HashSet<usize> =
            d.decision_log().iter().map(|x| x.anchor).collect();
        assert!(anchors.len() >= 2, "jobs stayed on {anchors:?}");
    }

    #[test]
    fn policy_knob_routes_tenant_to_fixed() {
        let _g = LOCK.lock().unwrap();
        let (d, path) = start("policy");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        rpc.set_policy(Policy::Fixed).unwrap();
        assert!(rpc.set_policy_name("themis").is_err());
        let catalog = Catalog::load_default().unwrap();
        let params = crate::testutil::alloc_operand_params(&mut rpc, &catalog, "mandelbrot");
        let jobs: Vec<Job> = (0..3)
            .map(|_| Job::new("mandelbrot", params.clone()).with_tiles(4))
            .collect();
        let _ = rpc.run(&jobs); // decisions land even if compute is stubbed
        // A fixed tenant keeps one region: no replication, one anchor.
        let anchors: std::collections::HashSet<usize> =
            d.decision_log().iter().map(|x| x.anchor).collect();
        assert_eq!(anchors.len(), 1, "fixed tenant moved: {anchors:?}");
        assert_eq!(d.stats().replications.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pause_resume_and_stats_roundtrip() {
        let _g = LOCK.lock().unwrap();
        let (_d, path) = start("pause");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        rpc.pause().unwrap();
        let s0 = rpc.sched_stats().unwrap();
        assert!(s0.paused);
        assert_eq!(s0.queued, 0);
        rpc.resume().unwrap();
        let s1 = rpc.sched_stats().unwrap();
        assert!(!s1.paused);
        // Connection still healthy.
        assert!(rpc.ping().is_ok());
    }

    #[test]
    fn shm_zero_copy_path() {
        let _g = LOCK.lock().unwrap();
        if !crate::testutil::pjrt_available() {
            eprintln!("skipping: PJRT backend unavailable (offline stub)");
            return;
        }
        let (_d, path) = start("shm");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let shm_path = std::env::temp_dir().join(format!("fos_shm_{}.bin", std::process::id()));
        let mut shm = SharedMem::create(&shm_path, 4 * 4096 * 2).unwrap();
        let xs: Vec<f32> = (0..4096).map(|i| (i % 97) as f32).collect();
        shm.write_f32(0, &xs).unwrap();
        let a = rpc.alloc(4 * 4096).unwrap();
        let o = rpc.alloc(4 * 4096).unwrap();
        rpc.import_shm(&shm.path, 0, 4096, a).unwrap();
        let job = Job::new("aes", vec![("in_data".into(), a), ("out_data".into(), o)]);
        rpc.run(&[job]).unwrap();
        rpc.export_shm(o, 4096, &shm.path, 4 * 4096).unwrap();
        let out = shm.read_f32(4 * 4096, 4096).unwrap();
        // ARX cipher is a bijection: output differs from input everywhere
        // except possibly a few fixed points; check it's not identity.
        let same = out.iter().zip(&xs).filter(|(a, b)| a == b).count();
        assert!(same < 100, "{same} unchanged values");
    }

    #[test]
    fn cluster_daemon_routes_and_reports_per_board() {
        let _g = LOCK.lock().unwrap();
        let path = sock("cluster");
        let catalog = Catalog::load_default().unwrap();
        let d = Daemon::start_cluster(
            &path,
            &[ShellBoard::Ultra96, ShellBoard::Zcu102],
            catalog.clone(),
            Policy::Elastic,
            PlacementKind::LeastLoaded,
        )
        .unwrap();
        assert_eq!(d.boards().len(), 2);
        let mut rpc = FpgaRpc::connect(&path).unwrap();

        // Cluster/board stats RPCs answer before any work arrives.
        let cs = rpc.cluster_stats().unwrap();
        assert_eq!(cs.placement, "least-loaded");
        assert_eq!(cs.boards.len(), 2);
        assert_eq!(cs.boards[0].board, "Ultra96");
        assert_eq!(cs.boards[1].board, "ZCU102");
        let b1 = rpc.board_stats(1).unwrap();
        assert_eq!(b1.index, 1);
        assert_eq!(b1.board, "ZCU102");
        assert!(rpc.board_stats(7).is_err(), "out-of-range board must error");

        // Two queued mandelbrot jobs: least-loaded routing must spread
        // them over both boards (the second sees the first's backlog).
        let params = crate::testutil::alloc_operand_params(&mut rpc, &catalog, "mandelbrot");
        let jobs: Vec<Job> = (0..2)
            .map(|_| Job::new("mandelbrot", params.clone()).with_tiles(20))
            .collect();
        // Operands are mirrored into every board's arena at write time,
        // so either board can run the job; decisions are made (and
        // logged) even when the compute backend is stubbed.
        let _ = rpc.run(&jobs);

        let merged = d.decision_log();
        let log0 = d.board_decision_log(0);
        let log1 = d.board_decision_log(1);
        assert_eq!(merged.len(), log0.len() + log1.len(), "logs must partition");
        assert!(!log0.is_empty(), "board 0 got no work");
        assert!(!log1.is_empty(), "board 1 got no work: {merged:?}");
        // Every decision stays inside its board's fabric.
        assert!(log0.iter().all(|x| x.anchor + x.span <= 3));
        assert!(log1.iter().all(|x| x.anchor + x.span <= 4));

        // Aggregate stats equal the per-board sums, and the per-board
        // atomics mirror the shard counters.
        let st = rpc.sched_stats().unwrap();
        let cs = rpc.cluster_stats().unwrap();
        let sum: u64 = cs.boards.iter().map(|b| b.reconfigs + b.reuses).sum();
        assert_eq!(sum, st.reconfigs + st.reuses);
        assert_eq!(sum, merged.len() as u64);
        assert_eq!(cs.routed, 2);
        let pb = &d.stats().per_board;
        assert_eq!(pb.len(), 2);
        let mirrored: u64 = pb
            .iter()
            .map(|b| b.reconfigs.load(Ordering::Relaxed) + b.reuses.load(Ordering::Relaxed))
            .sum();
        assert_eq!(mirrored, sum);
    }

    #[test]
    fn async_submit_wait_poll_completions_roundtrip() {
        let _g = LOCK.lock().unwrap();
        let (d, path) = start("async");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let catalog = Catalog::load_default().unwrap();
        // A named session with a QoS class (weight 2, quota 8).
        let tenant = rpc.set_session("acme", None, 2, 8).unwrap();
        let params = crate::testutil::alloc_operand_params(&mut rpc, &catalog, "sobel");

        // Pause dispatching so the pending state is observable.
        rpc.pause().unwrap();
        let t1 = rpc.submit(&[Job::new("sobel", params.clone()).with_tiles(2)]).unwrap();
        let t2 = rpc.submit(&[Job::new("sobel", params.clone()).with_tiles(2)]).unwrap();
        assert_ne!(t1, t2);
        assert!(rpc.poll(t1).unwrap().is_none(), "paused daemon: ticket must be pending");
        let st = rpc.sched_stats().unwrap();
        assert_eq!(st.queued, 2, "both submissions queued for admission");
        assert_eq!(st.admit_queued, 2);
        assert!(
            st.tenants.iter().any(|t| t.tenant == tenant && t.weight == 2 && t.queued == 2),
            "tenant stats missing: {:?}",
            st.tenants
        );

        rpc.resume().unwrap();
        // wait() settles and consumes t1 (ok or stubbed-compute error
        // — either way a reply, never a hang)…
        let _ = rpc.wait(t1);
        // …after which the ticket is unknown.
        assert!(matches!(rpc.wait(t1), Err(proto::ProtoError::Remote(_))));
        // completions drains t2 once it settles.
        let mut drained = Vec::new();
        for _ in 0..2000 {
            drained = rpc.completions().unwrap();
            if !drained.is_empty() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(drained.len(), 1, "exactly one settled ticket to drain");
        assert_eq!(drained[0].0, t2);
        assert!(rpc.completions().unwrap().is_empty(), "drained exactly once");
        // Both batches were scheduled and decided.
        assert_eq!(d.decision_log().len(), 2);
        use std::sync::atomic::Ordering::Relaxed;
        assert_eq!(d.stats().async_submits.load(Relaxed), 2);
        assert_eq!(d.stats().admitted.load(Relaxed), 2);
    }

    #[test]
    fn unknown_accelerator_reports_error() {
        let _g = LOCK.lock().unwrap();
        let (_d, path) = start("err");
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let job = Job::new("flux_capacitor", vec![]);
        assert!(matches!(rpc.run(&[job]), Err(proto::ProtoError::Remote(_))));
        // Connection still usable after an error.
        assert!(rpc.ping().is_ok());
    }
}
