//! Wire protocol: 4-byte little-endian length prefix + JSON document.
//!
//! Requests: `{"method": "...", ...}`; responses: `{"status": "ok", ...}`
//! or `{"status": "err", "error": "..."}`. Bulk f32 data rides as
//! base64 (own encoder — no vendored base64 crate) but the intended
//! path for large buffers is shared memory (`import`/`export`).

use crate::json::{parse, to_string, Value};
use std::fmt;
use std::io::{Read, Write};

pub const MAX_MSG: u32 = 64 << 20;

/// Oldest protocol version this build still speaks.
pub const PROTO_MIN: u32 = 2;
/// Newest protocol version this build speaks. v2 introduced `hello`
/// negotiation, token-authenticated `session`, tenant-scoped
/// [`BufferHandle`]s on every memory RPC, and the `audit` RPC; see
/// `daemon/PROTOCOL.md` §7 for the history.
pub const PROTO_MAX: u32 = 2;

/// A tenant-scoped, opaque, generational buffer reference — the only
/// memory naming a client ever sees. The daemon packs a slab slot in
/// the low 32 bits and a generation (starting at 1, bumped on free) in
/// the high 32, the same discipline as the reactor's connection slab:
/// a stale handle can never alias a recycled allocation, and the raw
/// physical address never crosses the wire. `BufferHandle(0)` is never
/// valid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BufferHandle(pub u64);

impl BufferHandle {
    /// The never-valid handle (generation 0 is never minted).
    pub const NULL: BufferHandle = BufferHandle(0);

    pub fn from_parts(slot: u32, generation: u32) -> BufferHandle {
        BufferHandle((u64::from(generation) << 32) | u64::from(slot))
    }

    pub fn from_raw(raw: u64) -> BufferHandle {
        BufferHandle(raw)
    }

    pub fn raw(self) -> u64 {
        self.0
    }

    pub fn slot(self) -> u32 {
        self.0 as u32
    }

    pub fn generation(self) -> u32 {
        (self.0 >> 32) as u32
    }
}

impl fmt::Display for BufferHandle {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "buf:{}.{}", self.slot(), self.generation())
    }
}

#[derive(Debug)]
pub enum ProtoError {
    Io(std::io::Error),
    TooLarge(u32),
    Json(String),
    Remote(String),
    Schema(String),
    /// Structured admission backpressure: the daemon's bounded
    /// per-tenant queue (or its connection table) is full.  Not a
    /// failure — retry after the hinted delay.
    Busy { message: String, retry_after_ms: u64 },
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "io: {e}"),
            ProtoError::TooLarge(n) => write!(f, "message of {n} bytes exceeds limit"),
            ProtoError::Json(e) => write!(f, "bad json: {e}"),
            ProtoError::Remote(e) => write!(f, "daemon error: {e}"),
            ProtoError::Schema(e) => write!(f, "bad message: {e}"),
            ProtoError::Busy { message, retry_after_ms } => {
                write!(f, "daemon busy (retry in ~{retry_after_ms} ms): {message}")
            }
        }
    }
}

impl std::error::Error for ProtoError {}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> Self {
        ProtoError::Io(e)
    }
}

/// One acceleration job (Listing 4/5): logical accelerator name +
/// register operands (tenant-scoped [`BufferHandle`]s from `alloc`) +
/// the number of work items batched behind those registers (the §4.4.2
/// request granularity the scheduler amortises reconfigurations over).
/// The daemon resolves handles to physical addresses at the trust
/// boundary; raw addresses never appear on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub accname: String,
    /// (register name, operand handle) pairs.
    pub params: Vec<(String, BufferHandle)>,
    /// Work items (tiles) in this request; 1 for a single call.
    pub tiles: usize,
}

impl Job {
    /// A single-tile job — the common Listing-4 shape.
    pub fn new(accname: impl Into<String>, params: Vec<(String, BufferHandle)>) -> Job {
        Job { accname: accname.into(), params, tiles: 1 }
    }

    pub fn with_tiles(mut self, tiles: usize) -> Job {
        self.tiles = tiles.max(1);
        self
    }

    pub fn to_value(&self) -> Value {
        use crate::json::{i, obj, s};
        obj(vec![
            ("name", s(self.accname.clone())),
            ("tiles", i(self.tiles as i64)),
            (
                "params",
                Value::Object(
                    self.params
                        .iter()
                        .map(|(k, v)| (k.clone(), i(v.raw() as i64)))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_value(v: &Value) -> Result<Job, ProtoError> {
        let accname = v
            .req_str("name")
            .map_err(ProtoError::Schema)?
            .to_string();
        // Absent on old clients: default to a single work item.
        let tiles = v.get("tiles").as_u64().unwrap_or(1).max(1) as usize;
        let params = v
            .get("params")
            .as_object()
            .ok_or_else(|| ProtoError::Schema("missing params".into()))?
            .iter()
            .map(|(k, val)| {
                val.as_u64()
                    .map(|x| (k.clone(), BufferHandle::from_raw(x)))
                    .ok_or_else(|| ProtoError::Schema(format!("param {k} not a buffer handle")))
            })
            .collect::<Result<_, _>>()?;
        Ok(Job { accname, params, tiles })
    }
}

pub fn write_msg(w: &mut impl Write, v: &Value) -> Result<(), ProtoError> {
    let body = to_string(v);
    let len = body.len() as u32;
    if len > MAX_MSG {
        return Err(ProtoError::TooLarge(len));
    }
    w.write_all(&len.to_le_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()?;
    Ok(())
}

pub fn read_msg(r: &mut impl Read) -> Result<Value, ProtoError> {
    let mut lenb = [0u8; 4];
    r.read_exact(&mut lenb)?;
    let len = u32::from_le_bytes(lenb);
    if len > MAX_MSG {
        return Err(ProtoError::TooLarge(len));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    let text = std::str::from_utf8(&buf).map_err(|e| ProtoError::Json(e.to_string()))?;
    parse(text).map_err(|e| ProtoError::Json(e.to_string()))
}

// --- base64 (standard alphabet, padded) -----------------------------------

const B64: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

pub fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity((data.len() + 2) / 3 * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let n = u32::from(b[0]) << 16 | u32::from(b[1]) << 8 | u32::from(b[2]);
        out.push(B64[(n >> 18) as usize & 63] as char);
        out.push(B64[(n >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 { B64[(n >> 6) as usize & 63] as char } else { '=' });
        out.push(if chunk.len() > 2 { B64[n as usize & 63] as char } else { '=' });
    }
    out
}

pub fn b64_decode(text: &str) -> Result<Vec<u8>, ProtoError> {
    let rev = |c: u8| -> Result<u32, ProtoError> {
        B64.iter()
            .position(|&x| x == c)
            .map(|p| p as u32)
            .ok_or_else(|| ProtoError::Schema(format!("bad base64 byte {c}")))
    };
    let bytes: Vec<u8> = text.bytes().filter(|&b| b != b'\n').collect();
    if bytes.len() % 4 != 0 {
        return Err(ProtoError::Schema("base64 length not a multiple of 4".into()));
    }
    let mut out = Vec::with_capacity(bytes.len() / 4 * 3);
    for q in bytes.chunks(4) {
        let pad = q.iter().filter(|&&c| c == b'=').count();
        let n = rev(q[0])? << 18
            | rev(q[1])? << 12
            | (if q[2] == b'=' { 0 } else { rev(q[2])? }) << 6
            | (if q[3] == b'=' { 0 } else { rev(q[3])? });
        out.push((n >> 16) as u8);
        if pad < 2 {
            out.push((n >> 8) as u8);
        }
        if pad < 1 {
            out.push(n as u8);
        }
    }
    Ok(out)
}

pub fn f32s_to_b64(data: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    b64_encode(&bytes)
}

pub fn b64_to_f32s(text: &str) -> Result<Vec<f32>, ProtoError> {
    let bytes = b64_decode(text)?;
    if bytes.len() % 4 != 0 {
        return Err(ProtoError::Schema("f32 payload not a multiple of 4".into()));
    }
    Ok(bytes
        .chunks(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{i, obj, s};

    #[test]
    fn framing_roundtrip() {
        let msg = obj(vec![("method", s("ping")), ("seq", i(42))]);
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        let back = read_msg(&mut buf.as_slice()).unwrap();
        assert_eq!(back, msg);
        // Two messages back to back.
        let mut buf2 = Vec::new();
        write_msg(&mut buf2, &msg).unwrap();
        write_msg(&mut buf2, &obj(vec![("method", s("x"))])).unwrap();
        let mut r = buf2.as_slice();
        assert_eq!(read_msg(&mut r).unwrap(), msg);
        assert_eq!(read_msg(&mut r).unwrap().req_str("method").unwrap(), "x");
    }

    #[test]
    fn truncated_stream_errors() {
        let msg = obj(vec![("method", s("ping"))]);
        let mut buf = Vec::new();
        write_msg(&mut buf, &msg).unwrap();
        buf.truncate(buf.len() - 3);
        assert!(matches!(read_msg(&mut buf.as_slice()), Err(ProtoError::Io(_))));
    }

    #[test]
    fn buffer_handle_packing() {
        let h = BufferHandle::from_parts(7, 3);
        assert_eq!(h.slot(), 7);
        assert_eq!(h.generation(), 3);
        assert_eq!(BufferHandle::from_raw(h.raw()), h);
        assert_eq!(BufferHandle::NULL.generation(), 0);
        assert_eq!(format!("{h}"), "buf:7.3");
    }

    #[test]
    fn job_listing4_shape() {
        let job = Job::new(
            "Partial_accel_vadd",
            vec![
                ("a_op".into(), BufferHandle::from_parts(0, 1)),
                ("b_op".into(), BufferHandle::from_parts(1, 1)),
                ("c_out".into(), BufferHandle::from_parts(2, 1)),
            ],
        );
        let v = job.to_value();
        assert_eq!(v.req_str("name").unwrap(), "Partial_accel_vadd");
        let back = Job::from_value(&v).unwrap();
        assert_eq!(back, job);
        // Batched work items survive the round-trip; old-style messages
        // without "tiles" default to 1.
        let batched = job.clone().with_tiles(8);
        assert_eq!(Job::from_value(&batched.to_value()).unwrap().tiles, 8);
        let mut legacy = batched.to_value();
        if let crate::json::Value::Object(fields) = &mut legacy {
            fields.retain(|k, _| k != "tiles");
        }
        assert_eq!(Job::from_value(&legacy).unwrap().tiles, 1);
    }

    #[test]
    fn base64_roundtrip() {
        for n in [0usize, 1, 2, 3, 4, 5, 100, 4096] {
            let data: Vec<u8> = (0..n).map(|i| (i * 37 % 251) as u8).collect();
            let enc = b64_encode(&data);
            assert_eq!(b64_decode(&enc).unwrap(), data, "n={n}");
        }
        assert_eq!(b64_encode(b"Man"), "TWFu");
        assert_eq!(b64_encode(b"Ma"), "TWE=");
        assert_eq!(b64_encode(b"M"), "TQ==");
        assert!(b64_decode("a!aa").is_err());
        assert!(b64_decode("aaa").is_err());
    }

    #[test]
    fn f32_payload_roundtrip() {
        let data = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        assert_eq!(b64_to_f32s(&f32s_to_b64(&data)).unwrap(), data);
    }
}
