//! Event-driven network plane: the daemon's reactor, shardable across
//! cores.
//!
//! One reactor thread (or N of them — `fos daemon --reactor-shards N`)
//! owns the client connections.  Each shard has its own [`Poller`]
//! (epoll(7) on Linux, poll(2) elsewhere), its own generational
//! [`Slab`] of connection state keyed by a `u64` token instead of a
//! thread per client, its own frame-reassembly buffers and its own
//! waker; requests assemble zero-copy inside a reusable
//! per-connection [`FrameBuf`]; replies batch into a per-connection
//! write buffer flushed as far as the kernel will take it, with the
//! remainder waiting on the next writable event.
//!
//! ## Sharding (N > 1)
//!
//! Unix sockets have no SO_REUSEPORT-style accept balancing, so a
//! dedicated `Acceptor` thread owns the listener and deals accepted
//! streams round-robin into per-shard handoff rings (an mpsc channel
//! each), poking the target shard's waker.  Every shard feeds the
//! *single* dispatcher thread through one bounded MPSC ingest queue
//! ([`std::sync::mpsc::SyncSender`]); replies route back to the owning
//! shard because each shard mints `ReplySink`s carrying its own
//! reply channel and waker.  The dispatcher and the virtual-time
//! completion heap stay single-threaded and byte-identical — sharding
//! moves socket work onto more cores, never scheduling decisions.
//!
//! Tokens stay globally unique across shards: the shard id is folded
//! into the top [`SHARD_BITS`] bits of every slab key, and connection
//! `user` ids are strided (`shard + k * nshards`), so a stale reply
//! can neither hit a recycled slot (generation check) nor another
//! shard's slot (tag check).  With one shard (the default) the tag is
//! zero and the layout — like every observable behaviour — is exactly
//! the single-reactor daemon's.
//!
//! The wire protocol the reactor frames is specified in
//! `rust/src/daemon/PROTOCOL.md`, and the RPC semantics are
//! byte-for-byte those of the old thread-per-connection server:
//!
//! * clients are strict write-one-read-one ([`crate::daemon::FpgaRpc`]),
//!   so at most **one** request per connection is in flight with the
//!   dispatcher at a time, and at most one serialized reply sits in the
//!   write buffer;
//! * while a request is in flight (or a reply is still flushing) the
//!   connection's read interest is dropped — a client that pipelines
//!   requests without draining replies is eventually backpressured by
//!   the kernel socket buffers, exactly as it was when a blocking
//!   thread served it, and daemon-side memory stays bounded;
//! * a malformed or oversized frame closes the connection silently
//!   (the blocking `read_msg` contract);
//! * at the connection cap a new client is shed with a best-effort
//!   `Busy { retry_after_ms }` frame before the close.
//!
//! Dispatcher replies travel back over an in-process channel as
//! `(slab key, Value)` pairs plus a `Waker` byte on a socketpair;
//! the slab key's generation makes a reply for an already-closed
//! connection drop harmlessly instead of landing on a recycled slot.

use std::io::{self, Read, Write};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use super::dispatch::DaemonStats;
use super::proto::{write_msg, MAX_MSG};
use super::session::{busy_val, decode_request, err_val, Decoded, Msg};
use crate::json::Value;

/// Connection-table cap of the default configuration: past this many
/// live connections the reactor sheds new clients with a structured
/// busy reject instead of growing the slab without bound.  With
/// reactor shards the cap is global — enforced over the shards' summed
/// live counts, not per shard.
pub const DEFAULT_MAX_CONNECTIONS: usize = 256;

/// Bits at the top of every slab key that carry the owning shard's id,
/// keeping connection tokens globally unique across reactor shards.
/// The generation below shrinks to [`EPOCH_BITS`] bits to make room;
/// both a stale generation *and* a foreign shard tag make a key miss.
pub const SHARD_BITS: u32 = 8;

/// Hard cap on `--reactor-shards` implied by [`SHARD_BITS`] (the two
/// reserved control tokens live at the very top of the key space, so
/// the last tag value is unusable).
pub const MAX_SHARDS: usize = (1 << SHARD_BITS) - 1;

/// Bits of per-slot generation left under the shard tag.  16M
/// generations per slot before wrap — the wrap is harmless unless a
/// reply outlives 2^24 reconnects of one slot, which the one-in-flight
/// discipline makes impossible.
pub const EPOCH_BITS: u32 = 32 - SHARD_BITS;

const EPOCH_MASK: u32 = (1 << EPOCH_BITS) - 1;

/// Socket read granularity (and the minimum spare tail a [`FrameBuf`]
/// guarantees).
const READ_CHUNK: usize = 4096;

/// Largest single growth step of a [`FrameBuf`] — big frames arrive in
/// bounded reallocation increments instead of one huge reserve.
const GROW_LIMIT: usize = 1 << 20;

/// Buffers larger than this shrink back once fully drained, so one
/// 64 MiB frame does not pin 64 MiB per connection forever.
const SHRINK_AT: usize = 256 * 1024;

/// Retained capacity after a shrink.
const INIT_CAP: usize = 16 * 1024;

/// One readiness notification out of [`Poller::wait`].
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// The token the file descriptor was registered under.
    pub token: u64,
    /// Readable — or hung up / errored, which a `read()` will observe.
    pub readable: bool,
    /// Writable — or errored, which a `write()` will observe.
    pub writable: bool,
}

#[cfg(target_os = "linux")]
mod sys {
    //! Raw epoll(7) FFI.  std links libc, so the symbols resolve
    //! without any external crate (the build environment is offline).

    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;

    use super::Event;

    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;

    // Kernel ABI: packed on x86-64 (the 64-bit data field is unaligned
    // there), naturally laid out on other architectures.
    #[derive(Clone, Copy)]
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    /// Readiness poller over epoll(7).
    pub struct Poller {
        epfd: RawFd,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn bits(read: bool, write: bool) -> u32 {
            let mut e = EPOLLRDHUP;
            if read {
                e |= EPOLLIN;
            }
            if write {
                e |= EPOLLOUT;
            }
            e
        }

        fn ctl(&mut self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Start watching `fd` under `token`.
        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, Self::bits(read, write), token)
        }

        /// Change the interest set of an already-watched `fd`.
        pub fn reregister(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, Self::bits(read, write), token)
        }

        /// Stop watching `fd` entirely.
        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Block until a registered fd is ready or `timeout_ms` elapses
        /// (negative = forever).  Fills `events`.
        pub fn wait(&mut self, events: &mut Events, timeout_ms: i32) -> io::Result<usize> {
            let n = unsafe {
                epoll_wait(
                    self.epfd,
                    events.buf.as_mut_ptr(),
                    events.buf.len() as c_int,
                    timeout_ms,
                )
            };
            if n < 0 {
                return Err(io::Error::last_os_error());
            }
            events.len = n as usize;
            Ok(events.len)
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }

    /// Reusable readiness-event buffer for [`Poller::wait`].
    pub struct Events {
        buf: Vec<EpollEvent>,
        len: usize,
    }

    impl Events {
        pub fn with_capacity(n: usize) -> Events {
            Events { buf: vec![EpollEvent { events: 0, data: 0 }; n.max(1)], len: 0 }
        }

        pub fn len(&self) -> usize {
            self.len
        }

        pub fn is_empty(&self) -> bool {
            self.len == 0
        }

        /// The `i`-th ready event of the last [`Poller::wait`] call.
        pub fn get(&self, i: usize) -> Event {
            assert!(i < self.len);
            let ev = self.buf[i];
            let bits = ev.events;
            Event {
                token: ev.data,
                readable: bits & (EPOLLIN | EPOLLRDHUP | EPOLLHUP | EPOLLERR) != 0,
                writable: bits & (EPOLLOUT | EPOLLHUP | EPOLLERR) != 0,
            }
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    //! Portable fallback poller over poll(2) for non-Linux Unixes.
    //! O(n) per wait, which is fine for a development machine; the
    //! deployment target is the epoll backend above.

    use std::io;
    use std::os::raw::{c_int, c_short, c_uint};
    use std::os::unix::io::RawFd;

    use super::Event;

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;

    #[derive(Clone, Copy)]
    #[repr(C)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_uint, timeout: c_int) -> c_int;
    }

    struct Entry {
        fd: RawFd,
        token: u64,
        read: bool,
        write: bool,
    }

    /// Readiness poller over poll(2).
    pub struct Poller {
        entries: Vec<Entry>,
    }

    impl Poller {
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { entries: Vec::new() })
        }

        pub fn register(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.entries.push(Entry { fd, token, read, write });
            Ok(())
        }

        pub fn reregister(
            &mut self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            match self.entries.iter_mut().find(|e| e.fd == fd) {
                Some(e) => {
                    e.token = token;
                    e.read = read;
                    e.write = write;
                    Ok(())
                }
                None => Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered")),
            }
        }

        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.entries.retain(|e| e.fd != fd);
            Ok(())
        }

        pub fn wait(&mut self, events: &mut Events, timeout_ms: i32) -> io::Result<usize> {
            events.out.clear();
            let mut fds: Vec<PollFd> = self
                .entries
                .iter()
                .map(|e| {
                    let mut ev: c_short = 0;
                    if e.read {
                        ev |= POLLIN;
                    }
                    if e.write {
                        ev |= POLLOUT;
                    }
                    PollFd { fd: e.fd, events: ev, revents: 0 }
                })
                .collect();
            if fds.is_empty() {
                return Ok(0);
            }
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_uint, timeout_ms) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            for (e, p) in self.entries.iter().zip(fds.iter()) {
                let r = p.revents;
                if r == 0 {
                    continue;
                }
                events.out.push(Event {
                    token: e.token,
                    readable: r & (POLLIN | POLLHUP | POLLERR) != 0,
                    writable: r & (POLLOUT | POLLHUP | POLLERR) != 0,
                });
                if events.out.len() == events.cap {
                    break;
                }
            }
            Ok(events.out.len())
        }
    }

    /// Reusable readiness-event buffer for [`Poller::wait`].
    pub struct Events {
        out: Vec<Event>,
        cap: usize,
    }

    impl Events {
        pub fn with_capacity(n: usize) -> Events {
            Events { out: Vec::with_capacity(n.max(1)), cap: n.max(1) }
        }

        pub fn len(&self) -> usize {
            self.out.len()
        }

        pub fn is_empty(&self) -> bool {
            self.out.is_empty()
        }

        /// The `i`-th ready event of the last [`Poller::wait`] call.
        pub fn get(&self, i: usize) -> Event {
            self.out[i]
        }
    }
}

pub use sys::{Events, Poller};

struct Slot<T> {
    epoch: u32,
    val: Option<T>,
}

/// Generational slab: dense storage addressed by a `u64` key carrying
/// the slot index in the low 32 bits, the slot's generation in the
/// next [`EPOCH_BITS`], and the owning shard's tag in the top
/// [`SHARD_BITS`].  Removing an entry bumps the generation, so a stale
/// key — say, a dispatcher reply for a connection that died while its
/// request was in flight — misses instead of landing on a recycled
/// slot; a key minted by another shard's slab misses on the tag even
/// if index and generation happen to line up.  [`Slab::new`] tags with
/// shard 0, which reproduces the pre-sharding key layout bit-for-bit
/// until a slot's generation first exceeds 2^24.
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    live: usize,
    /// Shard tag pre-shifted into key position (bits 56..64).
    tag: u64,
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab::with_shard(0)
    }

    /// A slab whose keys carry `shard` in their top [`SHARD_BITS`]
    /// bits.  Panics past [`MAX_SHARDS`] — the reserved control tokens
    /// (`u64::MAX`, `u64::MAX - 1`) live in the last tag's key space.
    pub fn with_shard(shard: usize) -> Slab<T> {
        assert!(shard < MAX_SHARDS, "shard {shard} exceeds MAX_SHARDS ({MAX_SHARDS})");
        Slab {
            slots: Vec::new(),
            free: Vec::new(),
            live: 0,
            tag: (shard as u64) << (32 + EPOCH_BITS),
        }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Decompose a key into `(tag, epoch, idx)`.
    fn split(key: u64) -> (u64, u32, usize) {
        (
            key >> (32 + EPOCH_BITS) << (32 + EPOCH_BITS),
            ((key >> 32) as u32) & EPOCH_MASK,
            (key & 0xffff_ffff) as usize,
        )
    }

    /// Insert, returning the entry's generational, shard-tagged key.
    pub fn insert(&mut self, val: T) -> u64 {
        let idx = match self.free.pop() {
            Some(i) => i as usize,
            None => {
                self.slots.push(Slot { epoch: 0, val: None });
                self.slots.len() - 1
            }
        };
        self.slots[idx].val = Some(val);
        self.live += 1;
        self.tag | (((self.slots[idx].epoch & EPOCH_MASK) as u64) << 32) | idx as u64
    }

    pub fn get(&self, key: u64) -> Option<&T> {
        let (tag, epoch, idx) = Self::split(key);
        if tag != self.tag {
            return None;
        }
        match self.slots.get(idx) {
            Some(slot) if slot.epoch & EPOCH_MASK == epoch => slot.val.as_ref(),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: u64) -> Option<&mut T> {
        let (tag, epoch, idx) = Self::split(key);
        if tag != self.tag {
            return None;
        }
        match self.slots.get_mut(idx) {
            Some(slot) if slot.epoch & EPOCH_MASK == epoch => slot.val.as_mut(),
            _ => None,
        }
    }

    /// Remove an entry; its slot's generation bumps so the key (and any
    /// stale copy of it) misses forever after.
    pub fn remove(&mut self, key: u64) -> Option<T> {
        let (tag, epoch, idx) = Self::split(key);
        if tag != self.tag {
            return None;
        }
        let slot = self.slots.get_mut(idx)?;
        if slot.epoch & EPOCH_MASK != epoch || slot.val.is_none() {
            return None;
        }
        let v = slot.val.take();
        slot.epoch = slot.epoch.wrapping_add(1);
        self.free.push(idx as u32);
        self.live -= 1;
        v
    }

    /// Take every live entry (reactor shutdown).
    pub fn drain(&mut self) -> Vec<T> {
        let mut out = Vec::with_capacity(self.live);
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(v) = slot.val.take() {
                slot.epoch = slot.epoch.wrapping_add(1);
                self.free.push(i as u32);
                out.push(v);
            }
        }
        self.live = 0;
        out
    }
}

impl<T> Default for Slab<T> {
    fn default() -> Slab<T> {
        Slab::new()
    }
}

/// Framing error out of [`FrameBuf::next_frame`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// The header announced a body larger than [`crate::daemon::MAX_MSG`].
    TooLarge(u32),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::TooLarge(n) => write!(fm, "frame of {n} bytes exceeds MAX_MSG"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Incremental assembly of `[u32 LE length][body]` frames over one
/// reusable buffer.
///
/// Socket bytes land in the spare tail handed out by
/// [`FrameBuf::space`] / committed by [`FrameBuf::commit`];
/// [`FrameBuf::next_frame`] then yields each complete frame body *in
/// place* — the returned slice borrows the buffer, no copy.  The buffer
/// grows in bounded steps toward a parsed header's announced length and
/// shrinks back once drained, so a single large frame does not pin its
/// peak allocation for the connection's lifetime.
pub struct FrameBuf {
    buf: Vec<u8>,
    start: usize,
    end: usize,
}

impl FrameBuf {
    pub fn new() -> FrameBuf {
        FrameBuf { buf: Vec::new(), start: 0, end: 0 }
    }

    /// Unconsumed buffered bytes (complete and partial frames).
    pub fn pending(&self) -> usize {
        self.end - self.start
    }

    /// Currently allocated buffer size — what the backpressure tests
    /// bound.
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    fn peek_len(&self) -> Option<u32> {
        if self.pending() < 4 {
            return None;
        }
        let b = &self.buf[self.start..self.start + 4];
        Some(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Spare tail to read into; at least `READ_CHUNK` (4 KiB), more
    /// when a parsed header says a large frame is mid-flight.  Follow
    /// with [`FrameBuf::commit`] for the bytes actually read.
    pub fn space(&mut self) -> &mut [u8] {
        if self.start == self.end {
            self.start = 0;
            self.end = 0;
            if self.buf.len() > SHRINK_AT {
                self.buf.truncate(INIT_CAP);
                self.buf.shrink_to(INIT_CAP);
            }
        }
        let mut chunk = READ_CHUNK;
        if let Some(len) = self.peek_len() {
            if len <= MAX_MSG {
                let need = (4 + len as usize).saturating_sub(self.pending());
                chunk = chunk.max(need.min(GROW_LIMIT));
            }
        }
        if self.buf.len() - self.end < chunk {
            if self.start > 0 {
                self.buf.copy_within(self.start..self.end, 0);
                self.end -= self.start;
                self.start = 0;
            }
            if self.buf.len() - self.end < chunk {
                let grow_to = self.end + chunk;
                self.buf.resize(grow_to, 0);
            }
        }
        &mut self.buf[self.end..]
    }

    /// Mark `n` bytes of the last [`FrameBuf::space`] slice as filled.
    pub fn commit(&mut self, n: usize) {
        self.end += n;
        debug_assert!(self.end <= self.buf.len());
    }

    /// The next complete frame body, in place; `Ok(None)` means more
    /// bytes are needed first.
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>, FrameError> {
        let Some(len) = self.peek_len() else { return Ok(None) };
        if len > MAX_MSG {
            return Err(FrameError::TooLarge(len));
        }
        let need = 4 + len as usize;
        if self.pending() < need {
            return Ok(None);
        }
        let body = self.start + 4;
        self.start += need;
        Ok(Some(&self.buf[body..body + len as usize]))
    }

    /// Append raw bytes — the test/bench seam standing in for a socket
    /// read (`space` + `commit` under the hood).
    pub fn extend(&mut self, bytes: &[u8]) {
        let mut off = 0;
        while off < bytes.len() {
            let dst = self.space();
            let n = dst.len().min(bytes.len() - off);
            dst[..n].copy_from_slice(&bytes[off..off + n]);
            self.commit(n);
            off += n;
        }
    }
}

impl Default for FrameBuf {
    fn default() -> FrameBuf {
        FrameBuf::new()
    }
}

/// Wakes the reactor out of [`Poller::wait`] from the dispatcher
/// thread: one byte down a socketpair, deduplicated by an atomic so a
/// storm of replies costs one write until the reactor drains it.
#[derive(Clone)]
pub(crate) struct Waker {
    tx: Arc<UnixStream>,
    armed: Arc<AtomicBool>,
}

impl Waker {
    fn new(tx: UnixStream) -> Waker {
        Waker { tx: Arc::new(tx), armed: Arc::new(AtomicBool::new(false)) }
    }

    /// Wake unless a wake is already pending.
    pub fn wake(&self) {
        if !self.armed.swap(true, Ordering::AcqRel) {
            let _ = (&*self.tx).write(&[1]);
        }
    }

    /// Unconditional wake — shutdown must never lose its wakeup to the
    /// deduplication race.
    pub fn wake_force(&self) {
        self.armed.store(true, Ordering::Release);
        let _ = (&*self.tx).write(&[1]);
    }

    fn disarm(&self) {
        self.armed.store(false, Ordering::Release);
    }
}

/// Where a dispatcher reply goes: straight back into an in-process
/// channel (daemon-internal queries, the old `ask()` shape) or to a
/// reactor connection addressed by its generational slab key.
pub(crate) enum ReplySink {
    Local(mpsc::Sender<Value>),
    Conn { key: u64, tx: mpsc::Sender<(u64, Value)>, waker: Waker },
}

impl ReplySink {
    pub fn send(&self, v: Value) {
        match self {
            ReplySink::Local(tx) => {
                let _ = tx.send(v);
            }
            ReplySink::Conn { key, tx, waker } => {
                if tx.send((*key, v)).is_ok() {
                    waker.wake();
                }
            }
        }
    }
}

/// Per-connection state held in the reactor's slab.
struct Conn {
    stream: UnixStream,
    user: u64,
    rbuf: FrameBuf,
    wbuf: Vec<u8>,
    wpos: usize,
    /// A request is with the dispatcher; its reply has not been queued.
    in_flight: bool,
    /// The peer hung up (or the socket errored); buffered complete
    /// frames still run before the connection closes.
    eof: bool,
    /// Currently registered poller interest, `None` when deregistered.
    interest: Option<(bool, bool)>,
}

impl Conn {
    fn new(stream: UnixStream, user: u64) -> Conn {
        Conn {
            stream,
            user,
            rbuf: FrameBuf::new(),
            wbuf: Vec::new(),
            wpos: 0,
            in_flight: false,
            eof: false,
            interest: None,
        }
    }

    fn write_pending(&self) -> bool {
        self.wpos < self.wbuf.len()
    }
}

const LISTENER_TOKEN: u64 = u64::MAX;
const WAKER_TOKEN: u64 = u64::MAX - 1;

/// What one parsed frame turned into (extracted as a step so the
/// connection borrow drops before the reactor acts on it).
enum Step {
    Dispatch(Value),
    Park,
    Close,
}

/// Drain a waker's self-wake pipe and disarm it so the next wake
/// writes a fresh byte.
fn drain_wake_pipe(rx: &UnixStream, waker: &Waker) {
    let mut buf = [0u8; 64];
    loop {
        match (&*rx).read(&mut buf) {
            Ok(0) => break,
            Ok(_) => continue,
            Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
    waker.disarm();
}

/// One shard of the daemon's event loop: frames, decodes and forwards
/// requests to the dispatcher thread, and flushes its replies — all on
/// one thread, one epoll set, zero threads per connection.  The
/// single-shard daemon (`--reactor-shards 1`, the default) runs one of
/// these owning the listener directly; with N > 1 shards each owns
/// only its connections and receives accepted streams over a handoff
/// ring from the dedicated [`Acceptor`].
pub(crate) struct Reactor {
    poller: Poller,
    /// The listening socket — `Some` only on the single-shard path
    /// (with N shards the `Acceptor` owns it).
    listener: Option<UnixListener>,
    /// Accept-handoff ring from the `Acceptor` — `Some` only when
    /// sharded; the acceptor pokes this shard's waker after pushing.
    handoff: Option<mpsc::Receiver<UnixStream>>,
    waker_rx: UnixStream,
    waker: Waker,
    conns: Slab<Conn>,
    tx: mpsc::SyncSender<Msg>,
    reply_tx: mpsc::Sender<(u64, Value)>,
    reply_rx: mpsc::Receiver<(u64, Value)>,
    stats: Arc<DaemonStats>,
    stop: Arc<AtomicBool>,
    max_connections: usize,
    /// Live connections summed over every shard — the connection cap
    /// is global, not per shard.
    live: Arc<AtomicUsize>,
    next_user: u64,
    /// `nshards`: striding keeps `user` ids globally unique without
    /// cross-shard coordination (shard s mints s, s+N, s+2N, …).
    user_stride: u64,
}

impl Reactor {
    /// Wire up a single-shard reactor around a bound listener — the
    /// default daemon topology, byte-identical to the pre-sharding
    /// reactor.  Returns the [`Waker`] handle `Daemon::shutdown` pokes.
    pub fn new(
        listener: UnixListener,
        tx: mpsc::SyncSender<Msg>,
        stats: Arc<DaemonStats>,
        stop: Arc<AtomicBool>,
        max_connections: usize,
    ) -> io::Result<(Reactor, Waker)> {
        listener.set_nonblocking(true)?;
        Self::build(
            Some(listener),
            None,
            0,
            1,
            tx,
            stats,
            stop,
            max_connections,
            Arc::new(AtomicUsize::new(0)),
        )
    }

    /// Wire up shard `shard` of an N-shard reactor plane: no listener
    /// (accepted streams arrive over `handoff` from the [`Acceptor`]),
    /// slab keys tagged with the shard id, user ids strided by
    /// `nshards`, and the connection cap enforced against the shared
    /// `live` count.
    #[allow(clippy::too_many_arguments)]
    pub fn shard(
        shard: usize,
        nshards: usize,
        handoff: mpsc::Receiver<UnixStream>,
        tx: mpsc::SyncSender<Msg>,
        stats: Arc<DaemonStats>,
        stop: Arc<AtomicBool>,
        max_connections: usize,
        live: Arc<AtomicUsize>,
    ) -> io::Result<(Reactor, Waker)> {
        Self::build(None, Some(handoff), shard, nshards, tx, stats, stop, max_connections, live)
    }

    #[allow(clippy::too_many_arguments)]
    fn build(
        listener: Option<UnixListener>,
        handoff: Option<mpsc::Receiver<UnixStream>>,
        shard: usize,
        nshards: usize,
        tx: mpsc::SyncSender<Msg>,
        stats: Arc<DaemonStats>,
        stop: Arc<AtomicBool>,
        max_connections: usize,
        live: Arc<AtomicUsize>,
    ) -> io::Result<(Reactor, Waker)> {
        let (wtx, wrx) = UnixStream::pair()?;
        wtx.set_nonblocking(true)?;
        wrx.set_nonblocking(true)?;
        let waker = Waker::new(wtx);
        let mut poller = Poller::new()?;
        if let Some(l) = &listener {
            poller.register(l.as_raw_fd(), LISTENER_TOKEN, true, false)?;
        }
        poller.register(wrx.as_raw_fd(), WAKER_TOKEN, true, false)?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let reactor = Reactor {
            poller,
            listener,
            handoff,
            waker_rx: wrx,
            waker: waker.clone(),
            conns: Slab::with_shard(shard),
            tx,
            reply_tx,
            reply_rx,
            stats,
            stop,
            max_connections,
            live,
            next_user: shard as u64,
            user_stride: nshards as u64,
        };
        Ok((reactor, waker))
    }

    /// Run until the stop flag is raised (and the waker poked).
    pub fn run(mut self) {
        let mut events = Events::with_capacity(1024);
        while !self.stop.load(Ordering::SeqCst) {
            match self.poller.wait(&mut events, -1) {
                Ok(_) => {}
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
            for k in 0..events.len() {
                let ev = events.get(k);
                match ev.token {
                    LISTENER_TOKEN => self.accept_ready(),
                    WAKER_TOKEN => {
                        drain_wake_pipe(&self.waker_rx, &self.waker);
                        self.drain_handoff();
                    }
                    key => self.conn_event(key, ev.readable, ev.writable),
                }
            }
            self.drain_replies();
        }
        // Shutdown: close every connection; the dispatcher hears one
        // Goodbye each, so per-user scheduler slots retire normally.
        // Streams still parked in the handoff ring were never admitted
        // (no user id, no slab slot) — dropping them is a clean EOF.
        if let Some(rx) = self.handoff.take() {
            while rx.try_recv().is_ok() {}
        }
        for conn in self.conns.drain() {
            self.live.fetch_sub(1, Ordering::AcqRel);
            let _ = self.tx.send(Msg::Goodbye { user: conn.user });
        }
    }

    fn accept_ready(&mut self) {
        let Some(listener) = self.listener.take() else { return };
        loop {
            match listener.accept() {
                Ok((stream, _)) => self.admit(stream),
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
        self.listener = Some(listener);
    }

    /// Pull every accepted stream the [`Acceptor`] handed this shard
    /// since the last wake.  No-op on the single-shard path.
    fn drain_handoff(&mut self) {
        let Some(rx) = self.handoff.take() else { return };
        while let Ok(stream) = rx.try_recv() {
            self.admit(stream);
        }
        self.handoff = Some(rx);
    }

    /// Admit or shed one accepted connection.  At the cap the client
    /// gets a best-effort `Busy { retry_after_ms: 50 }` frame and an
    /// immediate close — the same contract the thread-per-connection
    /// server honoured.  The cap is checked against the cross-shard
    /// `live` sum (reserve-then-verify, so concurrent shards can
    /// transiently reserve past the cap but never *keep* an admission
    /// beyond it).
    fn admit(&mut self, stream: UnixStream) {
        if self.live.fetch_add(1, Ordering::AcqRel) >= self.max_connections {
            self.live.fetch_sub(1, Ordering::AcqRel);
            self.stats.connections_shed.fetch_add(1, Ordering::Relaxed);
            let max = self.max_connections;
            let v = busy_val(&format!("daemon at connection capacity ({max})"), 50);
            let mut frame = Vec::new();
            if write_msg(&mut frame, &v).is_ok() {
                let _ = stream.set_nonblocking(true);
                let _ = (&stream).write(&frame);
            }
            return; // dropping the stream closes the client
        }
        if stream.set_nonblocking(true).is_err() {
            self.live.fetch_sub(1, Ordering::AcqRel);
            return;
        }
        let user = self.next_user;
        self.next_user += self.user_stride;
        let key = self.conns.insert(Conn::new(stream, user));
        let fd = match self.conns.get(key) {
            Some(c) => c.stream.as_raw_fd(),
            None => return,
        };
        if self.poller.register(fd, key, true, false).is_err() {
            self.conns.remove(key);
            self.live.fetch_sub(1, Ordering::AcqRel);
            return;
        }
        if let Some(c) = self.conns.get_mut(key) {
            c.interest = Some((true, false));
        }
    }

    fn conn_event(&mut self, key: u64, readable: bool, writable: bool) {
        if self.conns.get(key).is_none() {
            return; // stale readiness for a connection closed this sweep
        }
        if writable && !self.flush(key) {
            return;
        }
        if readable {
            self.fill(key);
        }
        if self.advance(key) {
            self.update_interest(key);
        }
    }

    /// Drain the socket into the frame buffer.  EOF and read errors
    /// both mark the connection `eof`; buffered complete frames still
    /// run before it closes.
    fn fill(&mut self, key: u64) {
        loop {
            let Some(conn) = self.conns.get_mut(key) else { return };
            if conn.eof {
                return;
            }
            let spare = conn.rbuf.space();
            match (&conn.stream).read(spare) {
                Ok(0) => {
                    conn.eof = true;
                    return;
                }
                Ok(n) => conn.rbuf.commit(n),
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.eof = true;
                    return;
                }
            }
        }
    }

    /// Parse and dispatch every actionable buffered frame, then close
    /// the connection if its peer is gone and nothing is left to do.
    /// Returns false when the connection was closed.
    fn advance(&mut self, key: u64) -> bool {
        loop {
            let step = {
                let Some(conn) = self.conns.get_mut(key) else { return false };
                if conn.in_flight || !conn.wbuf.is_empty() {
                    // One request in flight / one reply buffered at a
                    // time: the parse gate that bounds memory under a
                    // pipelining client.
                    Step::Park
                } else {
                    match conn.rbuf.next_frame() {
                        Ok(Some(frame)) => match std::str::from_utf8(frame)
                            .ok()
                            .and_then(|t| crate::json::parse(t).ok())
                        {
                            Some(v) => Step::Dispatch(v),
                            // Malformed JSON closes the connection
                            // silently — the blocking read_msg contract.
                            None => Step::Close,
                        },
                        Ok(None) => Step::Park,
                        // Oversized frame: same silent close.
                        Err(_) => Step::Close,
                    }
                }
            };
            match step {
                Step::Dispatch(v) => {
                    if !self.dispatch_one(key, v) {
                        return false;
                    }
                }
                Step::Park => break,
                Step::Close => {
                    self.close(key);
                    return false;
                }
            }
        }
        self.maybe_close(key)
    }

    /// Route one parsed request.  Returns false when the connection was
    /// closed.
    fn dispatch_one(&mut self, key: u64, msg: Value) -> bool {
        self.stats.rpcs.fetch_add(1, Ordering::Relaxed);
        let user = match self.conns.get(key) {
            Some(c) => c.user,
            None => return false,
        };
        let sink = ReplySink::Conn { key, tx: self.reply_tx.clone(), waker: self.waker.clone() };
        match decode_request(user, &msg, sink) {
            Decoded::Dispatch(m) => {
                if self.tx.send(m).is_ok() {
                    if let Some(c) = self.conns.get_mut(key) {
                        c.in_flight = true;
                    }
                    true
                } else {
                    // Dispatcher already gone: answer what ask() would.
                    self.enqueue_reply(key, err_val("daemon stopping"))
                }
            }
            Decoded::Immediate(v) => self.enqueue_reply(key, v),
            Decoded::Close => {
                self.close(key);
                false
            }
        }
    }

    /// Serialize a reply into the connection's write buffer and flush
    /// what the socket will take.  Returns false when the connection
    /// was closed.
    fn enqueue_reply(&mut self, key: u64, v: Value) -> bool {
        let serialized = match self.conns.get_mut(key) {
            Some(c) => write_msg(&mut c.wbuf, &v).is_ok(),
            None => return false,
        };
        if !serialized {
            self.close(key);
            return false;
        }
        self.flush(key)
    }

    /// Write as much buffered reply data as the kernel will take; the
    /// remainder waits for the next writable event (backpressure-aware
    /// flushing).  Returns false when the connection was closed.
    fn flush(&mut self, key: u64) -> bool {
        let mut broken = false;
        {
            let Some(conn) = self.conns.get_mut(key) else { return false };
            while conn.wpos < conn.wbuf.len() {
                match (&conn.stream).write(&conn.wbuf[conn.wpos..]) {
                    Ok(0) => {
                        broken = true;
                        break;
                    }
                    Ok(n) => conn.wpos += n,
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        broken = true;
                        break;
                    }
                }
            }
            if !broken && !conn.wbuf.is_empty() && conn.wpos == conn.wbuf.len() {
                conn.wbuf.clear();
                conn.wpos = 0;
                if conn.wbuf.capacity() > SHRINK_AT {
                    conn.wbuf.shrink_to(INIT_CAP);
                }
            }
        }
        if broken {
            self.close(key);
            return false;
        }
        self.update_interest(key);
        true
    }

    /// Deliver dispatcher replies queued since the last sweep, then
    /// resume parsing whatever those connections had buffered.
    fn drain_replies(&mut self) {
        while let Ok((key, v)) = self.reply_rx.try_recv() {
            match self.conns.get_mut(key) {
                Some(c) => c.in_flight = false,
                // Generation miss: the client died mid-request and the
                // slot may already be serving someone else — drop it.
                None => continue,
            }
            if !self.enqueue_reply(key, v) {
                continue;
            }
            if self.advance(key) {
                self.update_interest(key);
            }
        }
    }

    /// A connection whose peer hung up closes once every buffered
    /// complete frame has been dispatched and answered.  Returns false
    /// when it closed.
    fn maybe_close(&mut self, key: u64) -> bool {
        let done = match self.conns.get(key) {
            Some(c) => c.eof && !c.in_flight && c.wbuf.is_empty(),
            None => return false,
        };
        if done {
            self.close(key);
            return false;
        }
        true
    }

    /// Re-register exactly the interest the connection state needs:
    /// read only while idle (dropping read interest mid-request is what
    /// bounds per-connection memory — a pipelining client stops being
    /// read until its reply drains), write only while flushing, nothing
    /// while parked on the dispatcher (a closed peer would otherwise
    /// storm EPOLLHUP and spin the loop).
    fn update_interest(&mut self, key: u64) {
        let (fd, have, want) = match self.conns.get(key) {
            Some(c) => {
                let read = !c.in_flight && c.wbuf.is_empty() && !c.eof;
                let write = c.write_pending();
                let want = if read || write { Some((read, write)) } else { None };
                (c.stream.as_raw_fd(), c.interest, want)
            }
            None => return,
        };
        if have == want {
            return;
        }
        let res = match (have, want) {
            (Some(_), None) => self.poller.deregister(fd).map(|_| None),
            (None, Some((r, w))) => self.poller.register(fd, key, r, w).map(|_| want),
            (Some(_), Some((r, w))) => self.poller.reregister(fd, key, r, w).map(|_| want),
            (None, None) => return,
        };
        match res {
            Ok(interest) => {
                if let Some(c) = self.conns.get_mut(key) {
                    c.interest = interest;
                }
            }
            Err(_) => self.close(key),
        }
    }

    /// Tear down a connection: deregister, close the socket, and tell
    /// the dispatcher the user is gone (slot retirement, ticket and
    /// tenant-refcount cleanup).
    fn close(&mut self, key: u64) {
        if let Some(conn) = self.conns.remove(key) {
            self.live.fetch_sub(1, Ordering::AcqRel);
            if conn.interest.is_some() {
                let _ = self.poller.deregister(conn.stream.as_raw_fd());
            }
            let _ = self.tx.send(Msg::Goodbye { user: conn.user });
        }
    }
}

/// The dedicated accept thread of an N-shard reactor plane
/// (`--reactor-shards N`, N > 1).  Unix sockets have no
/// SO_REUSEPORT-style kernel accept balancing, so this owns the
/// listener outright and deals each accepted stream round-robin into a
/// shard's handoff ring, then pokes that shard's waker.  Admission —
/// the global connection cap, the busy-shed frame, user-id minting —
/// happens on the owning shard, exactly where it happens on the
/// single-shard path.
pub(crate) struct Acceptor {
    poller: Poller,
    listener: UnixListener,
    waker_rx: UnixStream,
    waker: Waker,
    /// One handoff ring + waker per shard, dealt round-robin.
    shards: Vec<(mpsc::Sender<UnixStream>, Waker)>,
    next: usize,
    stop: Arc<AtomicBool>,
}

impl Acceptor {
    /// Wire the acceptor around the bound listener.  Returns the
    /// [`Waker`] `Daemon::shutdown` pokes to break the poll wait.
    pub fn new(
        listener: UnixListener,
        shards: Vec<(mpsc::Sender<UnixStream>, Waker)>,
        stop: Arc<AtomicBool>,
    ) -> io::Result<(Acceptor, Waker)> {
        assert!(!shards.is_empty());
        listener.set_nonblocking(true)?;
        let (wtx, wrx) = UnixStream::pair()?;
        wtx.set_nonblocking(true)?;
        wrx.set_nonblocking(true)?;
        let waker = Waker::new(wtx);
        let mut poller = Poller::new()?;
        poller.register(listener.as_raw_fd(), LISTENER_TOKEN, true, false)?;
        poller.register(wrx.as_raw_fd(), WAKER_TOKEN, true, false)?;
        let acceptor = Acceptor {
            poller,
            listener,
            waker_rx: wrx,
            waker: waker.clone(),
            shards,
            next: 0,
            stop,
        };
        Ok((acceptor, waker))
    }

    /// Run until the stop flag is raised (and the waker poked).
    pub fn run(mut self) {
        let mut events = Events::with_capacity(64);
        while !self.stop.load(Ordering::SeqCst) {
            match self.poller.wait(&mut events, -1) {
                Ok(_) => {}
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
            for k in 0..events.len() {
                match events.get(k).token {
                    LISTENER_TOKEN => self.accept_ready(),
                    WAKER_TOKEN => drain_wake_pipe(&self.waker_rx, &self.waker),
                    _ => {}
                }
            }
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let (tx, waker) = &self.shards[self.next];
                    self.next = (self.next + 1) % self.shards.len();
                    // A shard that already exited dropped its ring
                    // receiver; the stream drops with the failed send
                    // and the client sees a clean EOF (shutdown only).
                    if tx.send(stream).is_ok() {
                        waker.wake();
                    }
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{obj, s};

    fn frame_bytes(v: &Value) -> Vec<u8> {
        let mut out = Vec::new();
        write_msg(&mut out, v).unwrap();
        out
    }

    #[test]
    fn framebuf_reassembles_across_partial_reads() {
        let bytes = frame_bytes(&obj(vec![("method", s("ping"))]));
        // Dribble one byte at a time; the frame pops out exactly once,
        // on the final byte.
        let mut fb = FrameBuf::new();
        let mut seen = 0;
        for (idx, byte) in bytes.iter().enumerate() {
            fb.extend(&[*byte]);
            match fb.next_frame() {
                Ok(Some(body)) => {
                    assert_eq!(idx, bytes.len() - 1);
                    assert_eq!(body, &bytes[4..]);
                    seen += 1;
                }
                Ok(None) => assert!(idx < bytes.len() - 1),
                Err(e) => panic!("unexpected framing error {e:?}"),
            }
        }
        assert_eq!(seen, 1);
        assert_eq!(fb.pending(), 0);
    }

    #[test]
    fn framebuf_yields_pipelined_frames_split_at_odd_boundaries() {
        let a = frame_bytes(&obj(vec![("method", s("ping"))]));
        let b = frame_bytes(&obj(vec![("method", s("stats"))]));
        let mut stream: Vec<u8> = Vec::new();
        stream.extend_from_slice(&a);
        stream.extend_from_slice(&b);
        // Split the two concatenated frames at every possible boundary:
        // the same two bodies must come out regardless of chunking.
        for cut in 1..stream.len() {
            let mut fb = FrameBuf::new();
            let mut bodies: Vec<Vec<u8>> = Vec::new();
            for chunk in [&stream[..cut], &stream[cut..]] {
                fb.extend(chunk);
                while let Ok(Some(body)) = fb.next_frame() {
                    bodies.push(body.to_vec());
                }
            }
            assert_eq!(bodies.len(), 2, "cut at {cut}");
            assert_eq!(bodies[0], &a[4..]);
            assert_eq!(bodies[1], &b[4..]);
        }
    }

    #[test]
    fn framebuf_rejects_oversized_header() {
        let mut fb = FrameBuf::new();
        fb.extend(&(MAX_MSG + 1).to_le_bytes());
        assert_eq!(fb.next_frame(), Err(FrameError::TooLarge(MAX_MSG + 1)));
        // Exactly MAX_MSG is still legal (merely incomplete here).
        let mut fb = FrameBuf::new();
        fb.extend(&MAX_MSG.to_le_bytes());
        assert_eq!(fb.next_frame(), Ok(None));
    }

    #[test]
    fn framebuf_grows_for_large_frames_then_shrinks() {
        let blob = "x".repeat(1 << 20);
        let bytes = frame_bytes(&obj(vec![("blob", s(blob))]));
        let mut fb = FrameBuf::new();
        for chunk in bytes.chunks(64 * 1024) {
            fb.extend(chunk);
        }
        {
            let body = fb.next_frame().unwrap().expect("complete frame");
            assert_eq!(body.len(), bytes.len() - 4);
        }
        assert!(fb.capacity() > SHRINK_AT, "grew to hold the 1 MiB frame");
        // The next idle space() call resets and releases the bulk.
        assert!(!fb.space().is_empty());
        assert!(fb.capacity() <= SHRINK_AT, "shrank back after draining");
    }

    #[test]
    fn slab_generation_prevents_stale_key_reuse() {
        let mut slab = Slab::new();
        let a = slab.insert("a");
        assert_eq!(slab.len(), 1);
        assert_eq!(slab.remove(a), Some("a"));
        let b = slab.insert("b");
        assert_eq!(a & 0xffff_ffff, b & 0xffff_ffff, "slot index is recycled");
        assert_ne!(a, b, "generation differs");
        assert!(slab.get(a).is_none(), "stale key misses");
        assert!(slab.remove(a).is_none(), "stale remove is a no-op");
        assert_eq!(slab.get(b), Some(&"b"));
        assert_eq!(slab.len(), 1);
    }

    #[test]
    fn slab_shard_tag_keeps_tokens_globally_unique() {
        let mut s0: Slab<&str> = Slab::with_shard(0);
        let mut s1: Slab<&str> = Slab::with_shard(1);
        let k0 = s0.insert("zero");
        let k1 = s1.insert("one");
        assert_eq!(k0 & 0xffff_ffff, k1 & 0xffff_ffff, "same slot index on both shards");
        assert_ne!(k0, k1, "shard tag separates the keys");
        assert_eq!(k1 >> (32 + EPOCH_BITS), 1, "tag rides the top bits");
        // Cross-shard lookups miss on the tag even though index and
        // generation line up exactly.
        assert!(s0.get(k1).is_none());
        assert!(s1.get(k0).is_none());
        assert!(s1.remove(k0).is_none(), "foreign-shard remove is a no-op");
        assert_eq!(s1.len(), 1);
        // Recycling a slot through several generations never mints
        // another shard's key.
        assert_eq!(s1.remove(k1), Some("one"));
        for _ in 0..8 {
            let k = s1.insert("again");
            assert_ne!(k, k0);
            assert_eq!(k >> (32 + EPOCH_BITS), 1, "tag survives slot recycling");
            assert_eq!(s1.remove(k), Some("again"));
        }
        // Shard 0 keys reproduce the pre-sharding layout (tag = 0).
        assert_eq!(k0 >> (32 + EPOCH_BITS), 0);
        assert_eq!(s0.get(k0), Some(&"zero"));
    }

    #[test]
    fn slab_with_shard_rejects_out_of_range_ids() {
        assert!(std::panic::catch_unwind(|| Slab::<u8>::with_shard(MAX_SHARDS)).is_err());
        let _ok: Slab<u8> = Slab::with_shard(MAX_SHARDS - 1);
    }

    #[test]
    fn slab_drain_empties_and_bumps_generations() {
        let mut slab = Slab::new();
        let k1 = slab.insert(1);
        let k2 = slab.insert(2);
        let mut drained = slab.drain();
        drained.sort_unstable();
        assert_eq!(drained, vec![1, 2]);
        assert!(slab.is_empty());
        assert!(slab.get(k1).is_none());
        assert!(slab.get(k2).is_none());
    }
}
