//! The accelerator catalog: typed view of `artifacts/manifest.json`.
//!
//! The manifest is the contract between the python AOT pipeline (L2/L1)
//! and this runtime: every accelerator's I/O shapes, Listing-2/3
//! register map, per-variant HLO artifact, netlist footprint and 100 MHz
//! cycle model. The catalog is the single source the registry, drivers,
//! scheduler and PJRT executor all read.

use crate::fabric::Resources;
use crate::json::{parse, Value};
use std::fmt;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        4 * self.elements() // all artifacts are f32 (DESIGN.md)
    }
}

/// One implementation alternative (resource-elastic variant, §4.4.2).
#[derive(Debug, Clone)]
pub struct Variant {
    pub name: String,
    pub hlo_file: String,
    /// Adjacent PR regions this variant occupies when loaded.
    pub regions: usize,
    /// Modelled cycles per work item at `clock_hz`.
    pub cycles_per_item: u64,
    pub clock_hz: u64,
    pub netlist: Resources,
}

impl Variant {
    /// Modelled pure-compute time for one work item (ns).
    pub fn compute_ns(&self) -> f64 {
        self.cycles_per_item as f64 * 1e9 / self.clock_hz as f64
    }
}

/// Listing-2/3 register map entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Register {
    pub name: String,
    pub offset: u64,
}

#[derive(Debug, Clone)]
pub struct Accelerator {
    pub name: String,
    /// Source language — the paper's heterogeneity axis (C / OpenCL / RTL).
    pub lang: String,
    pub suite: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub bytes_in: usize,
    pub bytes_out: usize,
    pub registers: Vec<Register>,
    /// Sorted by `regions` ascending; the last is the "biggest
    /// (Pareto-optimal, assumed fastest)" implementation (§4.4.3).
    pub variants: Vec<Variant>,
}

impl Accelerator {
    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// Largest variant that fits in `regions` adjacent free slots.
    pub fn best_variant_for(&self, regions: usize) -> Option<&Variant> {
        self.variants.iter().rev().find(|v| v.regions <= regions)
    }

    pub fn smallest_variant(&self) -> &Variant {
        &self.variants[0]
    }
}

#[derive(Debug)]
pub enum CatalogError {
    Io(std::io::Error),
    Json(String),
    Schema(String),
}

impl fmt::Display for CatalogError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CatalogError::Io(e) => write!(f, "catalog io: {e}"),
            CatalogError::Json(e) => write!(f, "catalog json: {e}"),
            CatalogError::Schema(e) => write!(f, "catalog schema: {e}"),
        }
    }
}

impl std::error::Error for CatalogError {}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Catalog {
    pub dir: PathBuf,
    pub clock_hz: u64,
    pub accelerators: Vec<Accelerator>,
}

impl Catalog {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Catalog, CatalogError> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(CatalogError::Io)?;
        Self::from_json_text(&text, dir)
    }

    /// Load from the workspace's default artifacts dir.
    pub fn load_default() -> Result<Catalog, CatalogError> {
        Self::load(crate::artifacts_dir())
    }

    pub fn from_json_text(text: &str, dir: PathBuf) -> Result<Catalog, CatalogError> {
        let v = parse(text).map_err(|e| CatalogError::Json(e.to_string()))?;
        let clock_hz = v
            .req_u64("clock_hz")
            .map_err(CatalogError::Schema)?;
        let mut accelerators = Vec::new();
        for a in v.req_array("accelerators").map_err(CatalogError::Schema)? {
            accelerators.push(parse_accel(a, clock_hz).map_err(CatalogError::Schema)?);
        }
        accelerators.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(Catalog { dir, clock_hz, accelerators })
    }

    pub fn get(&self, name: &str) -> Option<&Accelerator> {
        self.accelerators.iter().find(|a| a.name == name)
    }

    pub fn hlo_path(&self, variant: &Variant) -> PathBuf {
        self.dir.join(&variant.hlo_file)
    }

    pub fn names(&self) -> Vec<&str> {
        self.accelerators.iter().map(|a| a.name.as_str()).collect()
    }
}

fn tensor_specs(v: &Value, key: &str) -> Result<Vec<TensorSpec>, String> {
    v.req_array(key)?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                shape: t
                    .req_array("shape")?
                    .iter()
                    .map(|d| d.as_u64().ok_or("bad dim".to_string()).map(|x| x as usize))
                    .collect::<Result<_, _>>()?,
                dtype: t.req_str("dtype")?.to_string(),
            })
        })
        .collect()
}

fn parse_accel(a: &Value, default_clock: u64) -> Result<Accelerator, String> {
    let name = a.req_str("name")?.to_string();
    let registers = a
        .req_array("registers")?
        .iter()
        .map(|r| {
            Ok(Register {
                name: r.req_str("name")?.to_string(),
                offset: r.req_u64("offset")?,
            })
        })
        .collect::<Result<Vec<_>, String>>()?;
    let mut variants = a
        .req_array("variants")?
        .iter()
        .map(|v| {
            let nl = v.get("netlist");
            Ok(Variant {
                name: v.req_str("name")?.to_string(),
                hlo_file: v.req_str("hlo")?.to_string(),
                regions: v.req_u64("regions")? as usize,
                cycles_per_item: v.req_u64("cycles_per_item")?,
                clock_hz: v.get("clock_hz").as_u64().unwrap_or(default_clock),
                netlist: Resources {
                    luts: nl.req_u64("luts")? as usize,
                    ffs: nl.req_u64("ffs")? as usize,
                    brams: nl.req_u64("brams")? as usize,
                    dsps: nl.req_u64("dsps")? as usize,
                },
            })
        })
        .collect::<Result<Vec<Variant>, String>>()?;
    if variants.is_empty() {
        return Err(format!("accelerator {name} has no variants"));
    }
    variants.sort_by_key(|v| v.regions);
    Ok(Accelerator {
        name,
        lang: a.req_str("lang")?.to_string(),
        suite: a.req_str("suite")?.to_string(),
        inputs: tensor_specs(a, "inputs")?,
        outputs: tensor_specs(a, "outputs")?,
        bytes_in: a.req_u64("bytes_in")? as usize,
        bytes_out: a.req_u64("bytes_out")? as usize,
        registers,
        variants,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_built_manifest() {
        let c = Catalog::load_default().expect("run `make artifacts` first");
        assert_eq!(c.clock_hz, 100_000_000);
        assert_eq!(c.accelerators.len(), 10);
        let sobel = c.get("sobel").unwrap();
        assert_eq!(sobel.lang, "opencl");
        assert_eq!(sobel.inputs[0].shape, vec![128, 128]);
        assert_eq!(sobel.bytes_in, 128 * 128 * 4);
        assert_eq!(sobel.registers[0], Register { name: "control".into(), offset: 0 });
        assert_eq!(sobel.variants.len(), 2);
        assert!(sobel.variants[0].regions < sobel.variants[1].regions);
        // Bigger variant is faster (Pareto assumption, §4.4.3).
        assert!(sobel.variants[1].cycles_per_item < sobel.variants[0].cycles_per_item);
        // HLO artifacts exist on disk.
        for a in &c.accelerators {
            for v in &a.variants {
                assert!(c.hlo_path(v).exists(), "{}", v.hlo_file);
            }
        }
    }

    #[test]
    fn best_variant_selection() {
        let c = Catalog::load_default().unwrap();
        let dct = c.get("dct").unwrap();
        assert_eq!(dct.best_variant_for(1).unwrap().regions, 1);
        assert_eq!(dct.best_variant_for(2).unwrap().regions, 2);
        assert_eq!(dct.best_variant_for(3).unwrap().regions, 2);
        assert!(dct.best_variant_for(0).is_none());
        // AES is RTL-only: a single 1-region implementation.
        let aes = c.get("aes").unwrap();
        assert_eq!(aes.lang, "rtl");
        assert_eq!(aes.variants.len(), 1);
    }

    #[test]
    fn variant_compute_ns() {
        let c = Catalog::load_default().unwrap();
        let mandel = c.get("mandelbrot").unwrap();
        // 262144 cycles @ 100 MHz = 2.62144 ms.
        assert!((mandel.variants[0].compute_ns() - 2_621_440.0).abs() < 1.0);
    }

    #[test]
    fn schema_errors_are_reported() {
        let bad = r#"{"clock_hz": 1, "accelerators": [{"name": "x"}]}"#;
        let err = Catalog::from_json_text(bad, ".".into()).unwrap_err();
        assert!(matches!(err, CatalogError::Schema(_)));
        let notjson = Catalog::from_json_text("{", ".".into()).unwrap_err();
        assert!(matches!(notjson, CatalogError::Json(_)));
    }

    #[test]
    fn dct_superlinear_in_manifest() {
        let c = Catalog::load_default().unwrap();
        let dct = c.get("dct").unwrap();
        let speedup = dct.variants[0].cycles_per_item as f64
            / dct.variants[1].cycles_per_item as f64;
        assert!((speedup - 3.55).abs() < 0.1, "{speedup}");
    }
}
