//! Latency statistics and table printers for the evaluation harness.

use std::time::Duration;

/// Online latency recorder: count / mean / min / max / percentiles.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    pub fn min_us(&self) -> f64 {
        self.samples_us.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max_us(&self) -> f64 {
        self.samples_us.iter().copied().fold(0.0, f64::max)
    }

    pub fn percentile_us(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_us.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn summary(&self, label: &str) -> String {
        format!(
            "{label}: n={} mean={:.2}us p50={:.2}us p99={:.2}us min={:.2}us max={:.2}us",
            self.count(),
            self.mean_us(),
            self.percentile_us(50.0),
            self.percentile_us(99.0),
            self.min_us(),
            self.max_us()
        )
    }
}

/// Markdown-ish table printer used by every table/figure bench so the
/// output lines up with the paper's rows.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("| {c:<w$} "))
                .collect::<String>()
                + "|"
        };
        let mut out = format!("\n== {} ==\n{sep}\n{}\n{sep}\n", self.title, fmt_row(&self.headers));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a cell as `measured (paper: X)` for paper-vs-measured rows.
pub fn vs_paper(measured: f64, paper: f64, unit: &str) -> String {
    format!("{measured:.2}{unit} (paper {paper:.2}{unit})")
}

/// Virtual-time throughput: requests per second over a finished
/// simulator run (`makespan` in virtual ns) — the fig24 admission
/// comparison metric.
pub fn throughput_rps(requests: usize, makespan_ns: u64) -> f64 {
    requests as f64 / (makespan_ns.max(1) as f64 / 1e9)
}

/// p-th percentile over virtual-ns samples (nearest-rank on a sorted
/// copy; 0 for an empty set) — the fig24 ticket-latency reporter.
pub fn percentile_ns(samples: &[u64], p: f64) -> u64 {
    if samples.is_empty() {
        return 0;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// One-line summary of the shared scheduler-core counters — the same
/// [`crate::sched::SchedCounters`] both the simulator (`SimResult`) and
/// the daemon (`DaemonStats`) report from.
pub fn sched_summary(label: &str, c: &crate::sched::SchedCounters) -> String {
    format!(
        "{label}: {} reconfigs, {} reuses, {} skips, {} replications, {} preemptions, {} resumes",
        c.reconfigs, c.reuses, c.skips, c.replications, c.preemptions, c.resumes
    )
}

/// Multi-line per-board counter summary for cluster runs — one
/// [`sched_summary`] line per board shard (the fig23 report format;
/// the daemon's `DaemonStats::per_board` mirrors the same set).
pub fn cluster_summary(label: &str, boards: &[(String, crate::sched::SchedCounters)]) -> String {
    let mut out = format!("{label}:");
    for (name, c) in boards {
        out.push('\n');
        out.push_str("  ");
        out.push_str(&sched_summary(name, c));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = LatencyStats::new();
        for us in [1.0, 2.0, 3.0, 4.0, 100.0] {
            s.record_us(us);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean_us() - 22.0).abs() < 1e-9);
        assert_eq!(s.min_us(), 1.0);
        assert_eq!(s.max_us(), 100.0);
        assert_eq!(s.percentile_us(50.0), 3.0);
        assert_eq!(s.percentile_us(100.0), 100.0);
    }

    #[test]
    fn empty_stats_safe() {
        let s = LatencyStats::new();
        assert_eq!(s.mean_us(), 0.0);
        assert_eq!(s.percentile_us(99.0), 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["a", "long header", "c"]);
        t.row(&["1".into(), "2".into(), "3".into()]);
        t.row(&["wide cell".into(), "x".into(), "y".into()]);
        let r = t.render();
        assert!(r.contains("Demo"));
        assert!(r.contains("| wide cell "));
        // All data lines same width.
        let lines: Vec<&str> = r.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn table_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn throughput_and_percentile_basics() {
        assert_eq!(throughput_rps(10, 1_000_000_000), 10.0);
        assert_eq!(throughput_rps(0, 0), 0.0, "empty run must not divide by zero");
        let xs = [50u64, 10, 40, 20, 30];
        assert_eq!(percentile_ns(&xs, 50.0), 30);
        assert_eq!(percentile_ns(&xs, 100.0), 50);
        assert_eq!(percentile_ns(&[], 99.0), 0);
    }

    #[test]
    fn sched_summary_formats_shared_counters() {
        let c = crate::sched::SchedCounters {
            reconfigs: 3,
            reuses: 9,
            skips: 2,
            replications: 1,
            preemptions: 4,
            resumes: 4,
        };
        let s = sched_summary("elastic", &c);
        assert_eq!(
            s,
            "elastic: 3 reconfigs, 9 reuses, 2 skips, 1 replications, 4 preemptions, 4 resumes"
        );
    }

    #[test]
    fn cluster_summary_lists_each_board() {
        let mk = |reconfigs| crate::sched::SchedCounters { reconfigs, ..Default::default() };
        let s = cluster_summary(
            "locality x2",
            &[("Ultra96".to_string(), mk(3)), ("ZCU102".to_string(), mk(1))],
        );
        assert!(s.starts_with("locality x2:"));
        assert!(s.contains("\n  Ultra96: 3 reconfigs"));
        assert!(s.contains("\n  ZCU102: 1 reconfigs"));
    }
}
