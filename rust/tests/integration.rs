//! Cross-module integration: the decoupled compile flow feeding the
//! reconfiguration manager, the registry feeding the generic driver,
//! and the shell/memsim/catalog contracts holding together.

use fos::accel::Catalog;
use fos::bitstream::{extract, relocate, synth_full};
use fos::driver::{Cynq, RegisterFile};
use fos::fabric::{Device, DeviceKind, Floorplan, Resources};
use fos::memsim::{config_for, DdrModel};
use fos::pnr::{compile_fos, CostModel, Netlist};
use fos::reconfig::FpgaManager;
use fos::registry::Registry;
use fos::shell::{Shell, ShellBoard};

#[test]
fn compile_then_reconfigure_every_region() {
    // FOS flow output must be loadable into every PR slot via the
    // FPGA manager, with the decoupler protocol.
    let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
    let nl = Netlist::synthesize(
        "itest",
        &Resources { luts: 6000, ffs: 9000, brams: 10, dsps: 20 },
    );
    let report = compile_fos(&fp, &nl, &CostModel::default()).unwrap();
    let mut mgr = FpgaManager::new(fp.device.clone(), fp.regions.len());
    mgr.load_full(synth_full(&fp.device, 0));
    for (i, target) in fp.regions.iter().enumerate() {
        let moved = relocate(&fp.device, &report.partials[0], &fp.regions[0], target).unwrap();
        let lat = mgr.reconfigure_region(i, &moved).unwrap();
        assert!(lat.as_secs_f64() > 0.0);
    }
    assert_eq!(mgr.partial_loads, 3);
}

#[test]
fn bitstream_file_roundtrip_through_manager() {
    let fp = Floorplan::standard(Device::new(DeviceKind::Zu9eg));
    let full = synth_full(&fp.device, 9);
    let partial = extract(&fp.device, &full, &fp.regions[2]).unwrap();
    // Serialise to disk the way the registry's bitfiles are stored.
    let path = std::env::temp_dir().join(format!("fos_it_{}.bin", std::process::id()));
    std::fs::write(&path, partial.to_bytes()).unwrap();
    let back = fos::bitstream::Bitstream::from_bytes(&std::fs::read(&path).unwrap()).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(back, partial);
}

#[test]
fn registry_register_map_drives_generic_driver() {
    // The Listing-2 descriptor in the registry must be sufficient to
    // program an accelerator with the generic driver — no other source
    // of truth.
    let catalog = Catalog::load_default().unwrap();
    let shell = Shell::build(ShellBoard::Ultra96);
    let reg = Registry::populate(&shell, &catalog).unwrap();
    let desc = reg.accel("mm").unwrap();
    let registers: Vec<fos::accel::Register> = desc
        .req_array("registers")
        .unwrap()
        .iter()
        .map(|r| fos::accel::Register {
            name: r.req_str("name").unwrap().to_string(),
            offset: u64::from_str_radix(
                r.req_str("offset").unwrap().trim_start_matches("0x"),
                16,
            )
            .unwrap(),
        })
        .collect();
    let mut rf = RegisterFile::new(&registers);
    rf.write_by_name("a_op", 0x4000_0000).unwrap();
    rf.write_by_name("b_op", 0x4000_4000).unwrap();
    rf.write_by_name("c_out", 0x4000_8000).unwrap();
    assert_eq!(rf.operands().len(), 3);
}

#[test]
fn shell_ports_match_memsim_config() {
    for board in ShellBoard::all() {
        let shell = Shell::build(board);
        let cfg = config_for(board);
        assert_eq!(
            cfg.ports,
            board.axi_ports().len(),
            "{board:?}: memsim ports vs shell HP list"
        );
        assert_eq!(shell.region_count(), board.axi_ports().len());
    }
}

#[test]
fn every_variant_fits_its_claimed_regions_on_both_boards() {
    // Catalog netlists must be placeable in the PR regions they claim —
    // the contract between the python specs and the fabric.
    let catalog = Catalog::load_default().unwrap();
    for board in [ShellBoard::Ultra96, ShellBoard::Zcu102] {
        let shell = Shell::build(board);
        let region = shell.region_resources();
        for a in &catalog.accelerators {
            for v in &a.variants {
                let budget = region.scaled(v.regions);
                assert!(
                    v.netlist.fits_in(&budget),
                    "{} does not fit {} regions on {board:?}",
                    v.name,
                    v.regions
                );
            }
        }
    }
}

#[test]
fn data_manager_feeds_real_compute() {
    // Arena -> PJRT -> arena, via the Cynq glue, for a 2-input accel.
    let catalog = Catalog::load_default().unwrap();
    let mut fpga = Cynq::open(ShellBoard::Ultra96, catalog).unwrap();
    let taps: Vec<f32> = (0..16).map(|i| 1.0 / (i + 1) as f32).collect();
    let xs: Vec<f32> = (0..4111).map(|i| (i % 17) as f32).collect();
    let px = fpga.alloc(4 * 4111).unwrap();
    let pt = fpga.alloc(4 * 16).unwrap();
    let py = fpga.alloc(4 * 4096).unwrap();
    fpga.write_f32(px, &xs).unwrap();
    fpga.write_f32(pt, &taps).unwrap();
    let (h, _) = fpga.load_accelerator("fir", Some("fir_v1")).unwrap();
    fpga.write_reg(h, "x_op", px).unwrap();
    fpga.write_reg(h, "taps_op", pt).unwrap();
    fpga.write_reg(h, "y_out", py).unwrap();
    fpga.run(h).unwrap();
    let y = fpga.read_f32(py, 4096).unwrap();
    // CPU FIR reference at a few points.
    for &i in &[0usize, 100, 4095] {
        let want: f32 = (0..16).map(|j| taps[j] * xs[i + j]).sum();
        assert!((y[i] - want).abs() < 1e-3, "y[{i}]: {} vs {want}", y[i]);
    }
}

#[test]
fn memsim_transfer_consistent_with_steady_state() {
    let m = DdrModel::new(config_for(ShellBoard::Ultra96));
    // 1 MiB at the uncontended per-direction rate.
    let ns = m.transfer_ns(1 << 20, 0);
    let rate_mbps = (1 << 20) as f64 / (ns / 1e9) / 1e6;
    assert!((rate_mbps - 530.0).abs() < 60.0, "{rate_mbps}");
}
