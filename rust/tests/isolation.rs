//! Adversarial tenant-isolation suite: every cross-domain access a
//! hostile client can attempt over the wire must come back as a
//! structured denial/invalid reply — never data, never a daemon crash
//! — and the victim tenant's state must survive intact.
//!
//! Covers the isolation-domain contract end to end (PROTOCOL.md §2,
//! "Denied access"):
//!
//! - cross-tenant read/write/free with a stolen handle → `denied`,
//!   victim buffer intact;
//! - forged and stale (freed / generation-recycled) handles →
//!   `invalid buffer handle`;
//! - session bind with a wrong or missing token on an authenticated
//!   daemon → `denied`; `register-tenant` gated by the admin token;
//! - `hello` version negotiation: in-range offers bind the highest
//!   shared version, out-of-range offers get a structured err naming
//!   the daemon's range (not a silent close);
//! - `audit` returns only the calling tenant's decisions;
//! - under weighted bandwidth partitioning a latency-QoS tenant's
//!   tail latency stays bounded next to a saturating streamer.

use fos::accel::Catalog;
use fos::daemon::{
    read_msg, write_msg, BufferHandle, Daemon, DaemonConfig, FpgaRpc, Job, ProtoError,
    PROTO_MAX, PROTO_MIN,
};
use fos::json::{i, obj, s, Value};
use fos::sched::{simulate, AdmissionConfig, JobSpec, Policy, QosClass, SimConfig, Workload};
use fos::shell::ShellBoard;
use std::os::unix::net::UnixStream;

fn sock(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fos_iso_{name}_{}.sock", std::process::id()))
}

fn catalog() -> Catalog {
    Catalog::load_default().unwrap()
}

/// Raw framed request/reply on a bare socket (bypasses `FpgaRpc` so
/// tests can inspect the structured error fields of a reply).
fn roundtrip(c: &mut UnixStream, req: &Value) -> Value {
    write_msg(c, req).unwrap();
    read_msg(c).unwrap()
}

fn remote_msg(e: ProtoError) -> String {
    match e {
        ProtoError::Remote(m) => m,
        other => panic!("expected a structured remote error, got {other:?}"),
    }
}

#[test]
fn cross_tenant_access_is_denied_and_victim_survives() {
    let path = sock("xtenant");
    let _d = Daemon::start(&path, ShellBoard::Ultra96, catalog()).unwrap();

    let mut victim = FpgaRpc::connect(&path).unwrap();
    victim.set_session("acme", None, 1, 0).unwrap();
    let secret = victim.alloc(4 * 64).unwrap();
    let data: Vec<f32> = (0..64).map(|k| k as f32).collect();
    victim.write_f32(secret, &data).unwrap();

    let mut attacker = FpgaRpc::connect(&path).unwrap();
    attacker.set_session("evil", None, 1, 0).unwrap();

    // The stolen handle names a live buffer, but not the attacker's:
    // every memory RPC is refused with a denial, not an invalid-handle
    // error (the attacker learns nothing about arena layout either
    // way — the reply never carries the owner or an address).
    for err in [
        remote_msg(attacker.read_f32(secret, 64).unwrap_err()),
        remote_msg(attacker.write_f32(secret, &[0.0; 64]).unwrap_err()),
        remote_msg(attacker.free(secret).unwrap_err()),
    ] {
        assert!(err.contains("access denied"), "unexpected error: {err}");
        assert!(!err.contains("acme"), "error text leaks the owner: {err}");
    }

    // The attacker's connection survives its own denials...
    attacker.ping().unwrap();
    // ...and the victim's buffer is bit-for-bit intact.
    assert_eq!(victim.read_f32(secret, 64).unwrap(), data);

    // Structured shape on the wire: err + denied flag.
    let mut raw = UnixStream::connect(&path).unwrap();
    let bound = roundtrip(&mut raw, &obj(vec![("method", s("session")), ("tenant", s("evil"))]));
    assert_eq!(bound.get("status").as_str(), Some("ok"));
    let denied = roundtrip(
        &mut raw,
        &obj(vec![
            ("method", s("read")),
            ("handle", i(secret.raw() as i64)),
            ("count", i(64)),
        ]),
    );
    assert_eq!(denied.get("status").as_str(), Some("err"));
    assert_eq!(denied.get("denied").as_u64(), Some(1));
    assert!(denied.get("b64").as_str().is_none(), "denial must not carry data");
}

#[test]
fn forged_and_stale_handles_are_invalid() {
    let path = sock("forged");
    let _d = Daemon::start(&path, ShellBoard::Ultra96, catalog()).unwrap();
    let mut rpc = FpgaRpc::connect(&path).unwrap();

    // Forged: a raw value that never came from `alloc` (slot 99 does
    // not exist; generation 0 can never be valid either).
    for forged in [BufferHandle::from_raw((7 << 32) | 99), BufferHandle::from_raw(0)] {
        let err = remote_msg(rpc.read_f32(forged, 1).unwrap_err());
        assert!(err.contains("invalid buffer handle"), "unexpected error: {err}");
    }

    // Stale: freed handles die even when the slot is recycled — the
    // recycled slot carries a bumped generation, so the old handle
    // stays invalid while the new one works.
    let old = rpc.alloc(4 * 16).unwrap();
    rpc.write_f32(old, &[1.0; 16]).unwrap();
    rpc.free(old).unwrap();
    let err = remote_msg(rpc.read_f32(old, 16).unwrap_err());
    assert!(err.contains("invalid buffer handle"), "unexpected error: {err}");

    let fresh = rpc.alloc(4 * 16).unwrap();
    assert_ne!(fresh.raw(), old.raw(), "recycled slot must re-generation");
    rpc.write_f32(fresh, &[2.0; 16]).unwrap();
    assert_eq!(rpc.read_f32(fresh, 16).unwrap(), vec![2.0; 16]);
    let err = remote_msg(rpc.read_f32(old, 16).unwrap_err());
    assert!(err.contains("invalid buffer handle"), "stale handle revived: {err}");

    // Double free: the second one is invalid, not a crash.
    rpc.free(fresh).unwrap();
    assert!(rpc.free(fresh).is_err());
    rpc.ping().unwrap();
}

#[test]
fn authenticated_daemon_gates_session_binds() {
    let path = sock("auth");
    let cfg = DaemonConfig::new(&[ShellBoard::Ultra96], catalog()).tenants(&["acme", "bigco"]);
    let d = Daemon::start_configured(&path, cfg).unwrap();
    let acme_tok = d.tenant_token("acme").unwrap();
    let admin_tok = d.admin_token().unwrap();
    assert_ne!(acme_tok, admin_tok);
    assert!(d.tenant_token("ghost").is_none());

    let mut rpc = FpgaRpc::connect(&path).unwrap();
    // Missing token, wrong token, someone else's token, unknown tenant:
    // all denied with a structured error.
    for (tenant, token) in [
        ("acme", None),
        ("acme", Some("wrong")),
        ("acme", Some(admin_tok.as_str())),
        ("ghost", Some(acme_tok.as_str())),
    ] {
        let err = remote_msg(rpc.set_session(tenant, token, 1, 0).unwrap_err());
        assert!(err.contains("denied"), "unexpected error for {tenant:?}: {err}");
    }
    // The right token binds, on the same connection that was denied.
    rpc.set_session("acme", Some(&acme_tok), 2, 4).unwrap();
    let h = rpc.alloc(64).unwrap();
    rpc.free(h).unwrap();

    // Registration is an admin-gated control RPC: a bad admin token is
    // denied; the minted token then binds a brand-new tenant.
    let err = remote_msg(rpc.register_tenant("not-admin", "newco").unwrap_err());
    assert!(err.contains("denied"), "unexpected error: {err}");
    let newco_tok = rpc.register_tenant(&admin_tok, "newco").unwrap();
    let mut newco = FpgaRpc::connect(&path).unwrap();
    newco.set_session("newco", Some(&newco_tok), 1, 0).unwrap();

    // Structured denial shape for a bad bind on the wire.
    let mut raw = UnixStream::connect(&path).unwrap();
    let reply = roundtrip(&mut raw, &obj(vec![("method", s("session")), ("tenant", s("acme"))]));
    assert_eq!(reply.get("status").as_str(), Some("err"));
    assert_eq!(reply.get("denied").as_u64(), Some(1));
}

#[test]
fn hello_negotiates_v2_and_rejects_out_of_range_offers() {
    let path = sock("hello");
    let _d = Daemon::start(&path, ShellBoard::Ultra96, catalog()).unwrap();

    // The stock client lands on the daemon's newest version.
    let rpc = FpgaRpc::connect(&path).unwrap();
    assert_eq!(rpc.proto_version, PROTO_MAX);

    // An offer entirely above (or below) the daemon's range gets a
    // structured err naming the supported range — the connection stays
    // open so the client can surface the mismatch (no silent close).
    let mut raw = UnixStream::connect(&path).unwrap();
    for (lo, hi) in [(9, 12), (0, 1)] {
        let reply = roundtrip(
            &mut raw,
            &obj(vec![("method", s("hello")), ("min", i(lo)), ("max", i(hi))]),
        );
        assert_eq!(reply.get("status").as_str(), Some("err"));
        assert_eq!(reply.get("min_supported").as_u64(), Some(u64::from(PROTO_MIN)));
        assert_eq!(reply.get("max_supported").as_u64(), Some(u64::from(PROTO_MAX)));
        assert!(reply.get("error").as_str().unwrap_or("").contains("version"));
    }
    // A wider offer spanning the daemon's range binds its maximum.
    let reply = roundtrip(
        &mut raw,
        &obj(vec![("method", s("hello")), ("min", i(1)), ("max", i(40))]),
    );
    assert_eq!(reply.get("status").as_str(), Some("ok"));
    assert_eq!(reply.get("proto").as_u64(), Some(u64::from(PROTO_MAX)));
    // And the connection still serves requests after the failed offers.
    let pong = roundtrip(&mut raw, &obj(vec![("method", s("ping"))]));
    assert_eq!(pong.get("status").as_str(), Some("ok"));
}

#[test]
fn audit_shows_only_the_calling_tenants_decisions() {
    if !fos::testutil::pjrt_available() {
        eprintln!("skipping: PJRT backend unavailable (offline stub)");
        return;
    }
    let path = sock("audit");
    let _d = Daemon::start(&path, ShellBoard::Ultra96, catalog()).unwrap();

    let run_tenant = |tenant: &str, accel: &str, in_reg: &str, out_reg: &str, elems: usize| {
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let id = rpc.set_session(tenant, None, 1, 0).unwrap();
        assert!(rpc.audit(None).unwrap().is_empty(), "no decisions before any run");
        let input = rpc.alloc(4 * elems).unwrap();
        let output = rpc.alloc(4 * elems).unwrap();
        rpc.write_f32(input, &vec![0.5; elems]).unwrap();
        let jobs: Vec<Job> = (0..2)
            .map(|_| Job::new(accel, vec![(in_reg.into(), input), (out_reg.into(), output)]))
            .collect();
        rpc.run(&jobs).unwrap();
        (rpc, id)
    };

    let (mut a, a_id) = run_tenant("acme", "sobel", "in_img", "out_img", 128 * 128);
    let (mut b, b_id) = run_tenant("evil", "aes", "in_data", "out_data", 4096);
    assert_ne!(a_id, b_id);

    let a_log = a.audit(None).unwrap();
    let b_log = b.audit(Some(1)).unwrap();
    assert!(!a_log.is_empty() && !b_log.is_empty());
    assert!(b_log.len() <= 1, "limit respected");
    assert!(a_log.iter().all(|e| e.tenant == a_id && e.accel == "sobel"));
    assert!(b_log.iter().all(|e| e.tenant == b_id && e.accel == "aes"));
}

#[test]
fn bandwidth_partition_bounds_the_latency_tenant_under_saturation() {
    // Deterministic virtual-time check of the QoS bandwidth knob: a
    // weight-4 latency tenant's worst turnaround under a weight-1
    // saturating streamer must not degrade when partitioning replaces
    // the per-master equal split — and the streamer still finishes
    // (work-conserving shares, not reservations).
    let cat = catalog();
    let mut w = Workload::new();
    for k in 0..40 {
        w.push(JobSpec::stream(0, "sobel", Some("sobel_v1"), k * 50_000, 2));
    }
    // Two streams leave one PR region free on the 3-region Ultra96, so
    // the latency tenant really runs *concurrently* with the streamer
    // (pure region starvation would test the scheduler, not the
    // bandwidth model).
    for _ in 0..2 {
        w.push(JobSpec::stream(1, "mandelbrot", Some("mandelbrot_v1"), 0, 60));
    }
    w.set_qos(0, QosClass::new(4, usize::MAX));
    w.set_qos(1, QosClass::new(1, usize::MAX));

    let worst = |admission: AdmissionConfig| {
        let cfg = SimConfig::new(ShellBoard::Ultra96, Policy::Elastic).with_admission(admission);
        let r = simulate(&cat, &w, &cfg);
        let lat_worst = w
            .jobs
            .iter()
            .zip(&r.job_completion)
            .filter(|(j, _)| j.user == 0)
            .map(|(j, &c)| c.saturating_sub(j.arrival))
            .max()
            .unwrap();
        let stream_done = w
            .jobs
            .iter()
            .zip(&r.job_completion)
            .filter(|(j, _)| j.user == 1)
            .map(|(_, &c)| c)
            .max()
            .unwrap();
        (lat_worst, stream_done)
    };
    let (equal_split, stream_equal) = worst(AdmissionConfig::default());
    let (partitioned, stream_part) = worst(AdmissionConfig::default().with_bw_partition());
    assert!(
        partitioned as f64 <= equal_split as f64 * 1.10,
        "partitioning degraded the latency tenant: {equal_split} -> {partitioned} virtual ns"
    );
    assert!(stream_part > 0 && stream_equal > 0, "the streamer must still complete");
}
