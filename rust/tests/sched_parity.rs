//! Sim/daemon scheduling parity: the offline discrete-event simulator
//! and the live daemon share one scheduler core
//! (`fos::sched::SchedCore`), so driving the *same* multi-user job
//! trace through both must produce the *same* ordered sequence of
//! reuse/reconfigure decisions — variant, anchor, span and all.
//!
//! The daemon side uses `pause` to queue every tenant's jobs before the
//! first dispatch (mirroring the simulator's t=0 arrivals), then
//! `resume` and compares its decision log against `SimResult::decisions`.

use fos::accel::Catalog;
use fos::daemon::{Daemon, FpgaRpc, Job};
use fos::sched::{
    simulate, AdmissionConfig, Decision, DecisionKind, JobSpec, PlacementKind, Policy, QosClass,
    SimConfig, Sym, Workload,
};
use fos::shell::ShellBoard;
use std::collections::HashMap;
use std::path::PathBuf;

/// (kind, accel, variant, anchor, span, reconfigure, replicated, tiles)
///
/// Accel/variant are interned symbols; both harnesses derive the same
/// deterministic table from the shared catalog, so equal syms mean
/// equal names.
type Key = (DecisionKind, Sym, Sym, usize, usize, bool, bool, usize);

fn key(d: &Decision) -> Key {
    (d.kind, d.accel, d.variant, d.anchor, d.span, d.reconfigure, d.replicated, d.tiles)
}

fn sock(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fos_parity_{name}_{}.sock", std::process::id()))
}

#[test]
fn sim_and_daemon_make_identical_elastic_decisions() {
    // Two tenants with contended arrivals: same-accel sharing pressure
    // (reuse + reconfiguration avoidance) for one pair of users, and a
    // long backlog (replication + variant selection) for the other.
    let trace: &[(&str, usize, usize)] = &[("mandelbrot", 4, 4), ("sobel", 3, 2)];
    let catalog = Catalog::load_default().unwrap();

    // --- simulator side: all arrivals at t=0 ------------------------
    let mut w = Workload::new();
    for (u, &(accel, requests, tiles)) in trace.iter().enumerate() {
        w.push(JobSpec {
            user: u,
            accel: accel.to_string(),
            arrival: 0,
            requests,
            tiles_per_request: tiles,
            pin_variant: None,
        });
    }
    let sim = simulate(&catalog, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
    assert_eq!(sim.decisions.len(), 7, "sanity: every request decided once");

    // --- daemon side: pause, queue everything, resume ----------------
    let path = sock("elastic");
    let daemon = Daemon::start(&path, ShellBoard::Ultra96, catalog.clone()).unwrap();
    let mut control = FpgaRpc::connect(&path).unwrap();
    control.pause().unwrap();

    // Connect tenants sequentially so daemon user ids are ordered.
    let tenants: Vec<FpgaRpc> =
        trace.iter().map(|_| FpgaRpc::connect(&path).unwrap()).collect();
    let handles: Vec<_> = tenants
        .into_iter()
        .zip(trace.iter())
        .map(|(mut rpc, &(accel, requests, tiles))| {
            let catalog = catalog.clone();
            std::thread::spawn(move || {
                let params = fos::testutil::alloc_operand_params(&mut rpc, &catalog, accel);
                let jobs: Vec<Job> = (0..requests)
                    .map(|_| Job::new(accel, params.clone()).with_tiles(tiles))
                    .collect();
                // Decisions are logged even when the PJRT backend is a
                // stub and execution errors — tolerate either outcome.
                let _ = rpc.run(&jobs);
            })
        })
        .collect();

    // Wait until every request is admitted, then release the scheduler.
    let expected: u64 = trace.iter().map(|&(_, r, _)| r as u64).sum();
    for _ in 0..2000 {
        if control.sched_stats().unwrap().queued == expected {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(control.sched_stats().unwrap().queued, expected, "jobs not admitted");
    control.resume().unwrap();
    for h in handles {
        h.join().unwrap();
    }

    // --- compare ------------------------------------------------------
    let daemon_log = daemon.decision_log();
    let sim_seq: Vec<Key> = sim.decisions.iter().map(key).collect();
    let dmn_seq: Vec<Key> = daemon_log.iter().map(key).collect();
    assert_eq!(sim_seq, dmn_seq, "decision sequences diverged");

    // User identities differ (the daemon's control connection consumes
    // id 0) but must map 1:1 in order of first appearance.
    let mut map: HashMap<usize, usize> = HashMap::new();
    for (s, d) in sim.decisions.iter().zip(daemon_log.iter()) {
        let mapped = *map.entry(d.user).or_insert(s.user);
        assert_eq!(mapped, s.user, "user round-robin order diverged");
    }

    // Shared counters agree (same SchedCounters source on both paths).
    use std::sync::atomic::Ordering::Relaxed;
    let st = daemon.stats();
    assert_eq!(sim.counters.reconfigs, st.reconfig_loads.load(Relaxed));
    assert_eq!(sim.counters.reuses, st.reuse_hits.load(Relaxed));
    assert_eq!(sim.counters.skips, st.skips.load(Relaxed));
    assert_eq!(sim.counters.replications, st.replications.load(Relaxed));

    // The elastic live path must actually have replicated for this
    // backlog (the paper's Fig 20 effect on real hardware paths).
    assert!(
        st.replications.load(Relaxed) >= 1,
        "no replication on the live path: {dmn_seq:?}"
    );
}

#[test]
fn sim_and_daemon_parity_under_fixed_policy() {
    let trace: &[(&str, usize, usize)] = &[("dct", 3, 2), ("fir", 3, 2)];
    let catalog = Catalog::load_default().unwrap();

    let mut w = Workload::new();
    for (u, &(accel, requests, tiles)) in trace.iter().enumerate() {
        w.push(JobSpec {
            user: u,
            accel: accel.to_string(),
            arrival: 0,
            requests,
            tiles_per_request: tiles,
            pin_variant: None,
        });
    }
    let sim = simulate(&catalog, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Fixed));

    let path = sock("fixed");
    let daemon =
        Daemon::start_with_policy(&path, ShellBoard::Ultra96, catalog.clone(), Policy::Fixed)
            .unwrap();
    let mut control = FpgaRpc::connect(&path).unwrap();
    control.pause().unwrap();
    let tenants: Vec<FpgaRpc> =
        trace.iter().map(|_| FpgaRpc::connect(&path).unwrap()).collect();
    let handles: Vec<_> = tenants
        .into_iter()
        .zip(trace.iter())
        .map(|(mut rpc, &(accel, requests, tiles))| {
            let catalog = catalog.clone();
            std::thread::spawn(move || {
                let params = fos::testutil::alloc_operand_params(&mut rpc, &catalog, accel);
                let jobs: Vec<Job> = (0..requests)
                    .map(|_| Job::new(accel, params.clone()).with_tiles(tiles))
                    .collect();
                let _ = rpc.run(&jobs);
            })
        })
        .collect();
    let expected: u64 = trace.iter().map(|&(_, r, _)| r as u64).sum();
    for _ in 0..2000 {
        if control.sched_stats().unwrap().queued == expected {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    control.resume().unwrap();
    for h in handles {
        h.join().unwrap();
    }

    let daemon_log = daemon.decision_log();
    let sim_seq: Vec<_> = sim
        .decisions
        .iter()
        .map(|d| (d.accel, d.variant, d.span, d.reconfigure))
        .collect();
    let dmn_seq: Vec<_> = daemon_log
        .iter()
        .map(|d| (d.accel, d.variant, d.span, d.reconfigure))
        .collect();
    assert_eq!(sim_seq, dmn_seq);
    // Fixed policy: 1-region modules only, no replication.
    assert!(daemon_log.iter().all(|d| d.span == 1));
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(daemon.stats().replications.load(Relaxed), 0);
}

#[test]
fn sim_and_daemon_parity_with_preemption() {
    // A preemption-heavy trace: one tenant streams three long pinned
    // mandelbrot requests (enough to hold the whole Ultra96 fabric),
    // one tenant brings six short sobel requests. Under the quantum
    // policy the shorts' tenant checkpoints a stream mid-span; sim and
    // daemon must produce the identical decision sequence — Preempt
    // and Resume decisions included.
    let catalog = Catalog::load_default().unwrap();

    let mut w = Workload::new();
    for _ in 0..3 {
        w.push(JobSpec::stream(0, "mandelbrot", Some("mandelbrot_v1"), 0, 40));
    }
    for j in JobSpec::frame_pinned(1, "sobel", "sobel_v1", 0, 12, 6) {
        w.push(j);
    }
    let sim = simulate(&catalog, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Quantum));
    assert!(
        sim.counters.preemptions >= 1,
        "trace must actually preempt: {:?}",
        sim.counters
    );
    assert_eq!(sim.counters.preemptions, sim.counters.resumes);

    let path = sock("preempt");
    let daemon =
        Daemon::start_with_policy(&path, ShellBoard::Ultra96, catalog.clone(), Policy::Quantum)
            .unwrap();
    let mut control = FpgaRpc::connect(&path).unwrap();
    control.pause().unwrap();

    // Tenant 0: the streams (one request of 40 tiles each, pinned by
    // the daemon core itself on preemption); tenant 1: the shorts.
    let mut t0_rpc = FpgaRpc::connect(&path).unwrap();
    let mut t1_rpc = FpgaRpc::connect(&path).unwrap();
    let h0 = {
        let catalog = catalog.clone();
        std::thread::spawn(move || {
            let params = fos::testutil::alloc_operand_params(&mut t0_rpc, &catalog, "mandelbrot");
            let jobs: Vec<Job> = (0..3)
                .map(|_| Job::new("mandelbrot", params.clone()).with_tiles(40))
                .collect();
            let _ = t0_rpc.run(&jobs); // decisions land even if compute is stubbed
        })
    };
    let h1 = {
        let catalog = catalog.clone();
        std::thread::spawn(move || {
            let params = fos::testutil::alloc_operand_params(&mut t1_rpc, &catalog, "sobel");
            let jobs: Vec<Job> = (0..6)
                .map(|_| Job::new("sobel", params.clone()).with_tiles(2))
                .collect();
            let _ = t1_rpc.run(&jobs);
        })
    };

    for _ in 0..2000 {
        if control.sched_stats().unwrap().queued == 9 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(control.sched_stats().unwrap().queued, 9, "jobs not admitted");
    control.resume().unwrap();
    h0.join().unwrap();
    h1.join().unwrap();

    let daemon_log = daemon.decision_log();
    let sim_seq: Vec<Key> = sim.decisions.iter().map(key).collect();
    let dmn_seq: Vec<Key> = daemon_log.iter().map(key).collect();
    assert_eq!(sim_seq, dmn_seq, "preemptive decision sequences diverged");
    assert!(
        dmn_seq.iter().any(|k| k.0 == DecisionKind::Preempt),
        "live path made no Preempt decision: {dmn_seq:?}"
    );
    assert!(dmn_seq.iter().any(|k| k.0 == DecisionKind::Resume));

    // Shared counters agree, preemption counters included.
    use std::sync::atomic::Ordering::Relaxed;
    let st = daemon.stats();
    assert_eq!(sim.counters.reconfigs, st.reconfig_loads.load(Relaxed));
    assert_eq!(sim.counters.reuses, st.reuse_hits.load(Relaxed));
    assert_eq!(sim.counters.skips, st.skips.load(Relaxed));
    assert_eq!(sim.counters.preemptions, st.preemptions.load(Relaxed));
    assert_eq!(sim.counters.resumes, st.resumes.load(Relaxed));

    // The stats RPC exposes the preemption counters to tenants.
    let report = control.sched_stats().unwrap();
    assert_eq!(report.preemptions, sim.counters.preemptions);
    assert_eq!(report.resumes, sim.counters.resumes);
}

#[test]
fn sim_and_daemon_parity_with_tenant_qos_and_fair_share() {
    // Tenant-tagged parity through the QoS-enabled admission pipeline:
    // two tenants with different weights and tight in-flight quotas
    // under the FairShare policy and a finite DRR quantum.  The quota
    // forces multi-wave batched ingest (tokens only return at
    // completions), so this pins down that the daemon's admission
    // pipeline replays the simulator's ingest decision sequence —
    // tenant tags included.
    let catalog = Catalog::load_default().unwrap();
    let admission = AdmissionConfig { quantum_tiles: 8, ..AdmissionConfig::default() };

    let mut w = Workload::new();
    w.push(JobSpec {
        user: 0,
        accel: "mandelbrot".to_string(),
        arrival: 0,
        requests: 3,
        tiles_per_request: 8,
        pin_variant: None,
    });
    w.push(JobSpec {
        user: 1,
        accel: "sobel".to_string(),
        arrival: 0,
        requests: 6,
        tiles_per_request: 2,
        pin_variant: None,
    });
    w.set_qos(0, QosClass::new(2, 2));
    w.set_qos(1, QosClass::new(1, 2));
    let sim = simulate(
        &catalog,
        &w,
        &SimConfig::new(ShellBoard::Ultra96, Policy::FairShare).with_admission(admission),
    );
    assert_eq!(sim.decisions.len(), 9, "sanity: every request decided once");
    // The quota actually bit: with max_inflight 2 per tenant, the
    // first ingest admits at most 4 of the 9 requests.
    let admitted: u64 = sim.per_tenant.iter().map(|(_, c)| c.admitted).sum();
    assert_eq!(admitted, 9);

    let path = sock("qos");
    let daemon = Daemon::start_cluster_configured(
        &path,
        &[ShellBoard::Ultra96],
        catalog.clone(),
        Policy::FairShare,
        PlacementKind::Locality,
        admission,
        32,
    )
    .unwrap();
    let mut control = FpgaRpc::connect(&path).unwrap();
    control.pause().unwrap();

    // Sessions bound in tenant order (daemon tenant ids are assigned
    // in binding order, matching the simulator's user order).
    let mut t0_rpc = FpgaRpc::connect(&path).unwrap();
    let mut t1_rpc = FpgaRpc::connect(&path).unwrap();
    assert_eq!(t0_rpc.set_session("mandel-tenant", None, 2, 2).unwrap(), 0);
    assert_eq!(t1_rpc.set_session("sobel-tenant", None, 1, 2).unwrap(), 1);

    // The threads RETURN their connections so the tenants stay bound
    // (alive) while the per-tenant stats below are read — a dropped
    // connection's Goodbye retires its drained tenant from the
    // pipeline, which would race the assertions.
    let h0 = {
        let catalog = catalog.clone();
        std::thread::spawn(move || {
            let params = fos::testutil::alloc_operand_params(&mut t0_rpc, &catalog, "mandelbrot");
            let jobs: Vec<Job> = (0..3)
                .map(|_| Job::new("mandelbrot", params.clone()).with_tiles(8))
                .collect();
            let _ = t0_rpc.run(&jobs); // decisions land even if compute is stubbed
            t0_rpc
        })
    };
    let h1 = {
        let catalog = catalog.clone();
        std::thread::spawn(move || {
            let params = fos::testutil::alloc_operand_params(&mut t1_rpc, &catalog, "sobel");
            let jobs: Vec<Job> = (0..6)
                .map(|_| Job::new("sobel", params.clone()).with_tiles(2))
                .collect();
            let _ = t1_rpc.run(&jobs);
            t1_rpc
        })
    };
    for _ in 0..2000 {
        if control.sched_stats().unwrap().queued == 9 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(control.sched_stats().unwrap().queued, 9, "jobs not admitted");
    control.resume().unwrap();
    let _keep0 = h0.join().unwrap();
    let _keep1 = h1.join().unwrap();

    let daemon_log = daemon.decision_log();
    let sim_seq: Vec<Key> = sim.decisions.iter().map(key).collect();
    let dmn_seq: Vec<Key> = daemon_log.iter().map(key).collect();
    assert_eq!(sim_seq, dmn_seq, "QoS-gated decision sequences diverged");

    // Tenant tags map 1:1 in order of first appearance — the
    // tenant-tagged half of the parity claim.
    let mut tenant_map: HashMap<usize, usize> = HashMap::new();
    for (s, d) in sim.decisions.iter().zip(daemon_log.iter()) {
        let mapped = *tenant_map.entry(d.tenant).or_insert(s.tenant);
        assert_eq!(mapped, s.tenant, "tenant tag order diverged");
    }
    assert_eq!(tenant_map.len(), 2, "both tenants must appear in the log");

    // Per-tenant counters agree through the stats RPC.
    let st = control.sched_stats().unwrap();
    for (sim_tenant, c) in &sim.per_tenant {
        let daemon_tenant = tenant_map
            .iter()
            .find(|(_, &s)| s == *sim_tenant)
            .map(|(&d, _)| d as u64)
            .unwrap();
        let rep = st.tenants.iter().find(|t| t.tenant == daemon_tenant).unwrap();
        assert_eq!(rep.admitted, c.admitted, "tenant {sim_tenant} admitted diverged");
        assert_eq!(rep.completed, c.completed, "tenant {sim_tenant} completed diverged");
    }
}

#[test]
fn executor_attached_sim_is_deterministic_for_the_parity_trace() {
    // The output_checksum leg of the parity criterion: when real
    // compute is attached, the shared core's decision order fully
    // determines the data — two identical runs must produce identical
    // checksums over every computed tile. Skipped gracefully when the
    // PJRT backend is unavailable (offline stub).
    use fos::runtime::Executor;
    let catalog = Catalog::load_default().unwrap();
    let probe = Executor::new(catalog.clone());
    if probe.execute("vadd_v1", vec![vec![0.0; 4096], vec![0.0; 4096]]).is_err() {
        eprintln!("skipping checksum leg: PJRT backend unavailable");
        return;
    }
    let mut w = Workload::new();
    for j in JobSpec::frame(0, "vadd", 0, 4, 2) {
        w.push(j);
    }
    for j in JobSpec::frame(1, "dct", 0, 4, 2) {
        w.push(j);
    }
    let run = || {
        let mut cfg = SimConfig::new(ShellBoard::Ultra96, Policy::Elastic);
        cfg.executor = Some(Executor::new(catalog.clone()));
        simulate(&catalog, &w, &cfg)
    };
    let a = run();
    let b = run();
    assert_eq!(a.decisions, b.decisions);
    assert_ne!(a.output_checksum, 0xcbf29ce484222325, "no tiles computed");
    assert_eq!(a.output_checksum, b.output_checksum);
    assert_eq!(a.tiles_executed, 8);
}
