//! Rejected-request buffer coverage: a client naming an unknown
//! accelerator (or a policy naming an unknown variant) must get a
//! *structured* rejection — an error reply carrying the reason, never
//! a hang or a dead dispatcher — and `take_rejected` must drain each
//! rejection exactly once.

use fos::accel::Catalog;
use fos::daemon::{Daemon, FpgaRpc, Job, ProtoError};
use fos::sched::{
    ClusterCore, CostModel, PlaceReq, Placement, PlacementKind, Policy, RegionMap, SchedPolicy,
};
use fos::shell::ShellBoard;
use std::path::PathBuf;

fn sock(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fos_reject_{name}_{}.sock", std::process::id()))
}

#[test]
fn unknown_accelerator_gets_structured_rejection_not_a_hang() {
    let path = sock("unknown");
    let catalog = Catalog::load_default().unwrap();
    let _daemon = Daemon::start(&path, ShellBoard::Ultra96, catalog.clone()).unwrap();
    let mut rpc = FpgaRpc::connect(&path).unwrap();

    // The reply must be an error naming the accelerator — admission
    // rejects before any scheduling state is touched.
    let err = rpc.run(&[Job::new("flux_capacitor", vec![])]).unwrap_err();
    match err {
        ProtoError::Remote(msg) => {
            assert!(msg.contains("flux_capacitor"), "unhelpful rejection: {msg}")
        }
        other => panic!("expected a remote rejection, got {other:?}"),
    }

    // The connection (and the dispatcher) survive: a valid submission
    // afterwards is scheduled and decided.
    assert!(rpc.ping().is_ok());
    let params = fos::testutil::alloc_operand_params(&mut rpc, &catalog, "mandelbrot");
    let _ = rpc.run(&[Job::new("mandelbrot", params).with_tiles(2)]);
    let stats = rpc.sched_stats().unwrap();
    assert_eq!(stats.reconfigs + stats.reuses, 1, "valid job after rejection not scheduled");
}

#[test]
fn mixed_batch_reports_rejection_and_daemon_survives() {
    let path = sock("mixed");
    let catalog = Catalog::load_default().unwrap();
    let _daemon = Daemon::start(&path, ShellBoard::Ultra96, catalog.clone()).unwrap();
    let mut rpc = FpgaRpc::connect(&path).unwrap();

    // One valid + one unknown job in a single batch: the batch reply is
    // an error (the client learns the batch did not fully succeed), and
    // it arrives — the valid half must not leave the reply hanging.
    let params = fos::testutil::alloc_operand_params(&mut rpc, &catalog, "sobel");
    let jobs = vec![Job::new("sobel", params).with_tiles(1), Job::new("warp_drive", vec![])];
    match rpc.run(&jobs) {
        Err(ProtoError::Remote(msg)) => {
            assert!(msg.contains("warp_drive"), "rejection lost its reason: {msg}")
        }
        other => panic!("mixed batch must report the rejection, got {other:?}"),
    }

    // A second tenant is unaffected.
    let mut rpc2 = FpgaRpc::connect(&path).unwrap();
    assert!(rpc2.ping().is_ok());
    assert!(rpc2.sched_stats().is_ok());
}

/// A policy that always names a variant the catalog does not know —
/// the mid-flight rejection path (`next_decision` cannot panic the
/// dispatcher on a buggy policy).
struct BadVariant;

impl SchedPolicy for BadVariant {
    fn name(&self) -> &'static str {
        "bad-variant"
    }

    fn place(
        &mut self,
        _regions: &RegionMap,
        _costs: &CostModel,
        _req: &PlaceReq,
    ) -> Option<Placement> {
        Some(Placement { anchor: 0, variant: "not_a_variant".into(), reconfigure: true })
    }
}

#[test]
fn cluster_take_rejected_drains_exactly_once_per_shard() {
    let catalog = Catalog::load_default().unwrap();
    let mut cluster = ClusterCore::new(
        &[ShellBoard::Ultra96, ShellBoard::Zcu102],
        &catalog,
        Policy::Elastic,
        PlacementKind::RoundRobin,
    );
    for b in 0..2 {
        cluster.core_mut(b).register_policy(Box::new(BadVariant));
    }
    assert!(cluster.set_user_policy(0, "bad-variant"));

    // Unknown names are rejected at admission (before routing), so the
    // rejected buffer stays empty and round-robin does not advance.
    assert!(cluster.submit(0, 0, "flux_capacitor", 1, None).is_err());
    assert!(cluster.take_rejected(0).is_empty());

    // One request per board; both get rejected mid-flight by the buggy
    // policy, each into its own shard's buffer.
    assert_eq!(cluster.submit(0, 1, "vadd", 1, None).unwrap(), 0);
    assert_eq!(cluster.submit(0, 2, "vadd", 1, None).unwrap(), 1);
    for b in 0..2 {
        cluster.begin_round_at(b, 0);
        assert!(cluster.next_decision(b).is_none(), "board {b} must reject, not dispatch");
    }

    let r0 = cluster.take_rejected(0);
    assert_eq!(r0.len(), 1);
    assert_eq!(r0[0].0.job, 1);
    assert!(r0[0].1.contains("unknown variant"), "{}", r0[0].1);
    // Exactly once: a second drain is empty, and board 1's rejection
    // was not swept up by board 0's drain.
    assert!(cluster.take_rejected(0).is_empty());
    let r1 = cluster.take_rejected(1);
    assert_eq!(r1.len(), 1);
    assert_eq!(r1[0].0.job, 2);
    assert!(cluster.take_rejected(1).is_empty());
    assert!(!cluster.has_pending());
}
