//! Rejected-request buffer coverage: a client naming an unknown
//! accelerator (or a policy naming an unknown variant) must get a
//! *structured* rejection — an error reply carrying the reason, never
//! a hang or a dead dispatcher — and `take_rejected` must drain each
//! rejection exactly once.

use fos::accel::Catalog;
use fos::daemon::{Daemon, FpgaRpc, Job, ProtoError};
use fos::sched::{
    AdmissionConfig, ClusterCore, CostModel, PlaceReq, Placement, PlacementKind, Policy,
    RegionMap, SchedPolicy,
};
use fos::shell::ShellBoard;
use std::path::PathBuf;

fn sock(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fos_reject_{name}_{}.sock", std::process::id()))
}

#[test]
fn unknown_accelerator_gets_structured_rejection_not_a_hang() {
    let path = sock("unknown");
    let catalog = Catalog::load_default().unwrap();
    let _daemon = Daemon::start(&path, ShellBoard::Ultra96, catalog.clone()).unwrap();
    let mut rpc = FpgaRpc::connect(&path).unwrap();

    // The reply must be an error naming the accelerator — admission
    // rejects before any scheduling state is touched.
    let err = rpc.run(&[Job::new("flux_capacitor", vec![])]).unwrap_err();
    match err {
        ProtoError::Remote(msg) => {
            assert!(msg.contains("flux_capacitor"), "unhelpful rejection: {msg}")
        }
        other => panic!("expected a remote rejection, got {other:?}"),
    }

    // The connection (and the dispatcher) survive: a valid submission
    // afterwards is scheduled and decided.
    assert!(rpc.ping().is_ok());
    let params = fos::testutil::alloc_operand_params(&mut rpc, &catalog, "mandelbrot");
    let _ = rpc.run(&[Job::new("mandelbrot", params).with_tiles(2)]);
    let stats = rpc.sched_stats().unwrap();
    assert_eq!(stats.reconfigs + stats.reuses, 1, "valid job after rejection not scheduled");
}

#[test]
fn mixed_batch_reports_rejection_and_daemon_survives() {
    let path = sock("mixed");
    let catalog = Catalog::load_default().unwrap();
    let _daemon = Daemon::start(&path, ShellBoard::Ultra96, catalog.clone()).unwrap();
    let mut rpc = FpgaRpc::connect(&path).unwrap();

    // One valid + one unknown job in a single batch: the batch reply is
    // an error (the client learns the batch did not fully succeed), and
    // it arrives — the valid half must not leave the reply hanging.
    let params = fos::testutil::alloc_operand_params(&mut rpc, &catalog, "sobel");
    let jobs = vec![Job::new("sobel", params).with_tiles(1), Job::new("warp_drive", vec![])];
    match rpc.run(&jobs) {
        Err(ProtoError::Remote(msg)) => {
            assert!(msg.contains("warp_drive"), "rejection lost its reason: {msg}")
        }
        other => panic!("mixed batch must report the rejection, got {other:?}"),
    }

    // A second tenant is unaffected.
    let mut rpc2 = FpgaRpc::connect(&path).unwrap();
    assert!(rpc2.ping().is_ok());
    assert!(rpc2.sched_stats().is_ok());
}

#[test]
fn busy_backpressure_conserves_requests_and_always_replies() {
    // A bounded admission queue (cap 2) on a paused daemon: the first
    // two async submissions are accepted, everything past them gets a
    // structured Busy reply with a retry hint — and after resuming,
    // every accepted ticket settles.  Accepted + rejected must equal
    // submitted: backpressure never loses or duplicates a request.
    let path = sock("busy");
    let catalog = Catalog::load_default().unwrap();
    let daemon = Daemon::start_cluster_configured(
        &path,
        &[ShellBoard::Ultra96],
        catalog.clone(),
        Policy::Elastic,
        PlacementKind::Locality,
        AdmissionConfig { queue_cap: 2, ..AdmissionConfig::default() },
        16,
    )
    .unwrap();
    let mut control = FpgaRpc::connect(&path).unwrap();
    control.pause().unwrap();

    let mut rpc = FpgaRpc::connect(&path).unwrap();
    let params = fos::testutil::alloc_operand_params(&mut rpc, &catalog, "sobel");
    let mut accepted = Vec::new();
    let mut busy = 0u64;
    for _ in 0..6 {
        match rpc.submit(&[Job::new("sobel", params.clone()).with_tiles(1)]) {
            Ok(ticket) => accepted.push(ticket),
            Err(ProtoError::Busy { retry_after_ms, message }) => {
                assert!(retry_after_ms >= 1, "busy reply must carry a retry hint");
                assert!(message.contains("queue full"), "unhelpful busy reply: {message}");
                busy += 1;
            }
            Err(other) => panic!("expected a structured Busy, got {other:?}"),
        }
    }
    assert_eq!(accepted.len(), 2, "cap-2 queue must accept exactly two batches");
    assert_eq!(busy, 4);

    control.resume().unwrap();
    // Every accepted ticket settles with a reply (ok, or a stubbed-
    // compute error) — never a hang, never a dropped request.
    for ticket in &accepted {
        let _ = rpc.wait(*ticket);
    }
    let st = rpc.sched_stats().unwrap();
    assert_eq!(st.queued, 0, "accepted work fully drained");
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(daemon.stats().busy_rejections.load(Relaxed), 4);
    assert_eq!(daemon.stats().admitted.load(Relaxed), 2);
    assert_eq!(daemon.decision_log().len(), 2, "exactly the accepted requests were scheduled");
    // Per-tenant accounting agrees: 2 enqueued+completed, 4 busy.
    let tenant = st
        .tenants
        .iter()
        .find(|t| t.enqueued > 0)
        .expect("submitting tenant must be reported");
    assert_eq!(tenant.enqueued, 2);
    assert_eq!(tenant.admitted, 2);
    assert_eq!(tenant.completed, 2);
    assert_eq!(tenant.busy_rejected, 4);
    assert_eq!(tenant.inflight, 0);
    // The connection survives backpressure: a fresh submit after the
    // drain is accepted again.
    assert!(rpc.submit(&[Job::new("sobel", params).with_tiles(1)]).is_ok());
}

/// A policy that always names a variant the catalog does not know —
/// the mid-flight rejection path (`next_decision` cannot panic the
/// dispatcher on a buggy policy).
struct BadVariant;

impl SchedPolicy for BadVariant {
    fn name(&self) -> &'static str {
        "bad-variant"
    }

    fn place(
        &mut self,
        _regions: &RegionMap,
        _costs: &CostModel,
        req: &PlaceReq,
    ) -> Option<Placement> {
        // The accelerator's own symbol is a valid `Sym` that is never
        // one of its variant symbols — a variant the catalog does not
        // know.
        Some(Placement { anchor: 0, variant: req.accel_sym, reconfigure: true })
    }
}

#[test]
fn cluster_take_rejected_drains_exactly_once_per_shard() {
    let catalog = Catalog::load_default().unwrap();
    let mut cluster = ClusterCore::new(
        &[ShellBoard::Ultra96, ShellBoard::Zcu102],
        &catalog,
        Policy::Elastic,
        PlacementKind::RoundRobin,
    );
    for b in 0..2 {
        cluster.core_mut(b).register_policy(Box::new(BadVariant));
    }
    assert!(cluster.set_user_policy(0, "bad-variant"));

    // Unknown names are rejected at admission (before routing), so the
    // rejected buffer stays empty and round-robin does not advance.
    assert!(cluster.submit(0, 0, "flux_capacitor", 1, None).is_err());
    assert!(cluster.take_rejected(0).is_empty());

    // One request per board; both get rejected mid-flight by the buggy
    // policy, each into its own shard's buffer.
    assert_eq!(cluster.submit(0, 1, "vadd", 1, None).unwrap(), 0);
    assert_eq!(cluster.submit(0, 2, "vadd", 1, None).unwrap(), 1);
    for b in 0..2 {
        cluster.begin_round_at(b, 0);
        assert!(cluster.next_decision(b).is_none(), "board {b} must reject, not dispatch");
    }

    let r0 = cluster.take_rejected(0);
    assert_eq!(r0.len(), 1);
    assert_eq!(r0[0].0.job, 1);
    assert!(r0[0].1.contains("unknown variant"), "{}", r0[0].1);
    // Exactly once: a second drain is empty, and board 1's rejection
    // was not swept up by board 0's drain.
    assert!(cluster.take_rejected(0).is_empty());
    let r1 = cluster.take_rejected(1);
    assert_eq!(r1.len(), 1);
    assert_eq!(r1[0].0.job, 2);
    assert!(cluster.take_rejected(1).is_empty());
    assert!(!cluster.has_pending());
}
