//! Property-based tests over the core invariants (own `testutil::cases`
//! driver — no proptest in the offline vendor set).  Case counts obey
//! the `FOS_PROPTEST_CASES` env knob (`testutil::prop_cases`): the
//! nightly CI job sets it to run every property at long iteration
//! counts, tier-1 runs keep the fast defaults.

use fos::accel::Catalog;
use fos::bitstream::{extract, relocate, synth_full, Bitstream};
use fos::driver::{DataManager, PhysAddr};
use fos::fabric::{Device, DeviceKind, Floorplan};
use fos::json::{parse, to_string, to_string_pretty, Value};
use fos::sched::{
    simulate, simulate_cluster, AdmissionConfig, AdmissionPipeline, AdmitRequest, ClusterSimConfig,
    DecisionKind, JobSpec, OrderStrategy, PlacementKind, Policy, QosClass, Scenario, SchedCore,
    SimConfig, Workload, PREEMPT_TICK_NS,
};
use fos::shell::{Shell, ShellBoard};
use fos::testutil::{cases, prop_cases, Rng};

/// Random JSON value generator.
fn gen_value(rng: &mut Rng, depth: usize) -> Value {
    match if depth == 0 { rng.below(5) } else { rng.below(7) } {
        0 => Value::Null,
        1 => Value::Bool(rng.bool(0.5)),
        2 => Value::Int(rng.next_u64() as i64 / 2),
        3 => Value::Float((rng.f64() - 0.5) * 1e9),
        4 => {
            let n = rng.below(12) as usize;
            Value::Str(
                (0..n)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' { c as char } else { '\u{263A}' }
                    })
                    .collect(),
            )
        }
        5 => Value::Array(
            (0..rng.below(5)).map(|_| gen_value(rng, depth - 1)).collect(),
        ),
        _ => Value::Object(
            (0..rng.below(5))
                .map(|k| (format!("k{k}_{}", rng.below(100)), gen_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    cases(prop_cases(300), |rng| {
        let v = gen_value(rng, 3);
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
        assert_eq!(parse(&to_string_pretty(&v)).unwrap(), v);
    });
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    cases(prop_cases(500), |rng| {
        let n = rng.below(64) as usize;
        let junk: String = (0..n)
            .map(|_| *rng.pick(&['{', '}', '[', ']', '"', ',', ':', '1', 'e', '.', '-', 'n', 't', ' ']))
            .collect();
        let _ = parse(&junk); // must return, never panic
    });
}

#[test]
fn prop_bitstream_serialisation_roundtrip() {
    cases(prop_cases(60), |rng| {
        let mut bs = Bitstream::new("dev", rng.bool(0.5));
        for _ in 0..rng.below(20) {
            let addr = fos::bitstream::FrameAddr {
                clock_region: rng.below(8) as u32,
                column: rng.below(100) as u32,
                minor: rng.below(36) as u32,
            };
            let words = (0..fos::bitstream::FRAME_WORDS)
                .map(|_| rng.next_u64() as u32)
                .collect();
            bs.insert(fos::bitstream::Frame::new(addr, words));
        }
        assert_eq!(Bitstream::from_bytes(&bs.to_bytes()).unwrap(), bs);
        // Any single-bit corruption is detected (CRC or structure checks).
        let mut bytes = bs.to_bytes();
        if !bytes.is_empty() {
            let idx = rng.below(bytes.len() as u64) as usize;
            bytes[idx] ^= 1 << rng.below(8);
            assert!(Bitstream::from_bytes(&bytes).is_err());
        }
    });
}

#[test]
fn prop_relocation_is_invertible_and_content_preserving() {
    let fp = Floorplan::standard(Device::new(DeviceKind::Zu9eg));
    let full = synth_full(&fp.device, 77);
    cases(prop_cases(40), |rng| {
        let from = rng.below(fp.regions.len() as u64) as usize;
        let to = rng.below(fp.regions.len() as u64) as usize;
        let p = extract(&fp.device, &full, &fp.regions[from]).unwrap();
        let moved = relocate(&fp.device, &p, &fp.regions[from], &fp.regions[to]).unwrap();
        let back = relocate(&fp.device, &moved, &fp.regions[to], &fp.regions[from]).unwrap();
        assert_eq!(back, p);
        // Content multiset preserved.
        let mut a: Vec<&Vec<u32>> = p.frames.values().collect();
        let mut b: Vec<&Vec<u32>> = moved.frames.values().collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    });
}

#[test]
fn prop_data_manager_never_overlaps() {
    cases(prop_cases(60), |rng| {
        let mut dm = DataManager::new(1 << 18);
        let mut live: Vec<(PhysAddr, usize)> = Vec::new();
        for _ in 0..40 {
            if rng.bool(0.6) || live.is_empty() {
                let size = 1 + rng.below(8192) as usize;
                if let Ok(addr) = dm.alloc(size) {
                    // No overlap with any live allocation.
                    for &(a, s) in &live {
                        let disjoint = addr.0 + size as u64 <= a.0 || a.0 + s as u64 <= addr.0;
                        assert!(disjoint, "{addr:?}+{size} overlaps {a:?}+{s}");
                    }
                    live.push((addr, size));
                }
            } else {
                let idx = rng.below(live.len() as u64) as usize;
                let (addr, _) = live.swap_remove(idx);
                dm.free(addr).unwrap();
            }
        }
        // Accounting is exact.
        assert_eq!(dm.allocated_bytes(), live.iter().map(|&(_, s)| s).sum::<usize>());
    });
}

#[test]
fn prop_scheduler_trace_invariants_random_workloads() {
    let catalog = Catalog::load_default().unwrap();
    let accels = ["vadd", "mm", "fir", "histogram", "dct", "sobel", "mandelbrot", "black_scholes"];
    cases(prop_cases(25), |rng| {
        let mut w = Workload::new();
        let users = 1 + rng.below(4) as usize;
        for u in 0..users {
            let accel = *rng.pick(&accels);
            let tiles = 1 + rng.below(40) as usize;
            let reqs = 1 + rng.below(8) as usize;
            let arrival = rng.below(10_000_000);
            for j in JobSpec::frame(u, accel, arrival, tiles, reqs) {
                w.push(j);
            }
        }
        let board = if rng.bool(0.5) { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 };
        let policy = if rng.bool(0.5) { Policy::Elastic } else { Policy::Fixed };
        let r = simulate(&catalog, &w, &SimConfig::new(board, policy));
        let n_regions = if board == ShellBoard::Ultra96 { 3 } else { 4 };

        // Every request dispatched exactly once.
        assert_eq!(r.trace.len(), w.total_requests());
        assert_eq!(r.counters.reconfigs + r.counters.reuses, w.total_requests() as u64);
        // No overlapping allocations on any region; all inside fabric.
        for (i, a) in r.trace.iter().enumerate() {
            assert!(a.end > a.start);
            assert!(a.region + a.span <= n_regions, "{a:?}");
            for b in &r.trace[i + 1..] {
                let disjoint_regions =
                    a.region + a.span <= b.region || b.region + b.span <= a.region;
                let disjoint_time = a.end <= b.start || b.end <= a.start;
                assert!(disjoint_regions || disjoint_time, "{a:?} vs {b:?}");
            }
        }
        // Job completion happens after arrival and not after makespan.
        for (j, &done) in r.job_completion.iter().enumerate() {
            assert!(done >= w.jobs[j].arrival);
            assert!(done <= r.makespan);
        }
        assert!(r.regions.iter().map(|t| t.busy_ns).sum::<u64>() > 0);
    });
}

#[test]
fn prop_sched_core_bookkeeping_consistent_under_interleavings() {
    // Drive the bare core through arbitrary interleavings of
    // submit/round/complete/evict/retire_user/drain_pending (the full
    // harness surface) and check conservation: no request is ever lost
    // or double-dispatched, and the counters/decision log stay in sync
    // with the dispatch count — preemptive policies included.
    let catalog = Catalog::load_default().unwrap();
    let accels = ["vadd", "fir", "dct", "sobel", "mandelbrot"];
    let policies = [Policy::Elastic, Policy::Fixed, Policy::Quantum, Policy::ElasticPreempt];
    cases(prop_cases(30), |rng| {
        let policy = *rng.pick(&policies);
        let board =
            if rng.bool(0.5) { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 };
        let shell = Shell::build(board);
        let n_regions = shell.region_count();
        let mut core = SchedCore::new(&shell, catalog.clone(), policy);

        let mut now = 0u64;
        let mut submitted = 0u64; // accepted submits
        let mut dispatched = 0u64; // Run + Resume decisions (queue pops)
        let mut preempts = 0u64; // Preempt decisions (queue pushes)
        let mut retired = 0u64;
        let mut drained = 0u64;
        let mut rejects = 0u64;
        // Checkpoints whose resume-request left via retire/drain.
        let mut dropped_ckpts = 0u64;
        let mut busy: Vec<usize> = Vec::new(); // anchors we owe a complete()
        let mut next_job = 0u64;

        for _ in 0..60 {
            match rng.below(6) {
                // submit
                0 | 1 => {
                    let user = rng.below(4) as usize;
                    let accel = *rng.pick(&accels);
                    let tiles = 1 + rng.below(30) as usize;
                    let job = next_job;
                    next_job += 1;
                    core.submit(user, job, accel, tiles, None).unwrap();
                    submitted += 1;
                }
                // dispatch round
                2 => {
                    core.begin_round_at(now);
                    while let Some(d) = core.next_decision() {
                        match d.kind {
                            DecisionKind::Preempt => {
                                preempts += 1;
                                busy.retain(|&a| a != d.anchor);
                            }
                            DecisionKind::Run | DecisionKind::Resume => {
                                dispatched += 1;
                                let lat =
                                    core.service_ns(&d, core.busy_anchors().saturating_sub(1));
                                core.mark_running(&d, now, now + lat.max(1));
                                busy.push(d.anchor);
                            }
                        }
                    }
                    rejects += core.take_rejected().len() as u64;
                }
                // complete a running anchor
                3 => {
                    if !busy.is_empty() {
                        let idx = rng.below(busy.len() as u64) as usize;
                        let anchor = busy.swap_remove(idx);
                        core.complete(anchor);
                    }
                }
                // evict (failed-load rollback) anywhere
                4 => {
                    core.evict(rng.below(n_regions as u64) as usize);
                }
                // retire a user or drain everything
                _ => {
                    let reqs = if rng.bool(0.7) {
                        let n = core.retire_user(rng.below(4) as usize);
                        retired += n.len() as u64;
                        n
                    } else {
                        let n = core.drain_pending();
                        drained += n.len() as u64;
                        n
                    };
                    dropped_ckpts +=
                        reqs.iter().filter(|r| r.resume.is_some()).count() as u64;
                }
            }
            now += rng.below(10_000_000);

            // Conservation after every op: each accepted submit and
            // each preemption pushes exactly one queued request; each
            // dispatch, retire, drain and reject pops exactly one.
            let pending = core.pending() as u64;
            assert_eq!(
                submitted + preempts,
                dispatched + pending + retired + drained + rejects,
                "requests lost or duplicated (policy {policy:?})"
            );
            let c = core.counters();
            assert_eq!(c.reconfigs + c.reuses, dispatched, "placement counters drifted");
            assert_eq!(c.preemptions, preempts);
            assert!(c.resumes <= c.preemptions, "resume without a checkpoint");
            assert_eq!(
                core.decision_log().count() as u64,
                dispatched + preempts,
                "decision log out of sync"
            );
        }

        // Every checkpoint is live, consumed by a resume, or dropped
        // with its retired/drained request — an exact partition.
        let c = core.counters().clone();
        assert_eq!(
            core.checkpoints().count() as u64,
            c.preemptions - c.resumes - dropped_ckpts
        );
    });
}

#[test]
fn prop_floorplan_mutations_caught() {
    cases(prop_cases(60), |rng| {
        let mut fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        let idx = rng.below(fp.regions.len() as u64) as usize;
        let mutation = rng.below(4);
        match mutation {
            0 => fp.regions[idx].bbox.r0 += 1 + rng.below(30) as usize, // misalign
            1 => {
                fp.regions[idx].bbox.c0 += 1; // footprint shift
                fp.regions[idx].bbox.c1 += 1;
            }
            2 => fp.regions[idx].tunnel_rows = vec![rng.below(20) as usize], // tunnel move
            _ => {
                let other = (idx + 1) % fp.regions.len();
                fp.regions[idx].bbox = fp.regions[other].bbox; // overlap
            }
        }
        assert!(
            !fp.check().is_empty(),
            "mutation {mutation} on region {idx} went undetected"
        );
    });
}

#[test]
fn prop_admission_drr_share_tracks_weights_without_starvation() {
    // The admission pipeline's weighted-DRR guarantee, driven directly:
    // fully backlogged tenants with random weights behind a finite
    // per-round budget.  (a) No starvation: every tenant keeps
    // admitting within a bounded window of rounds.  (b) Weighted
    // share: each tenant's admitted-tile fraction tracks its weight
    // fraction (DRR bounds the deviation by a couple of quanta plus
    // one maximal request, far inside the asserted tolerance at this
    // round count).
    cases(prop_cases(25), |rng| {
        let n = 2 + rng.below(3) as usize; // 2..=4 tenants
        let quantum = 4u64;
        // Per-round budget comfortably above one full credit pass
        // (sum of quantum x weight <= 48 tiles), so the budget bounds
        // the round without distorting the per-pass weighted split.
        let batch = 64usize;
        let mut p = AdmissionPipeline::new(AdmissionConfig {
            queue_cap: usize::MAX,
            quantum_tiles: quantum,
            batch_cap: batch,
            ..AdmissionConfig::default()
        });
        let mut weights = vec![0u32; n];
        let mut job = 0u64;
        for t in 0..n {
            weights[t] = 1 + rng.below(3) as u32; // 1..=3
            p.set_qos(t, QosClass::new(weights[t], usize::MAX));
            // Adversarial backlog: mostly shorts, some streams — deep
            // enough that no queue drains within the measured rounds.
            for _ in 0..8000 {
                let tiles = if rng.bool(0.2) {
                    8 + rng.below(5) as usize // streams: 8..=12 tiles
                } else {
                    1 + rng.below(4) as usize // shorts: 1..=4 tiles
                };
                p.enqueue(AdmitRequest {
                    user: t,
                    tenant: t,
                    job,
                    accel: "vadd".to_string(),
                    tiles,
                    pin: None,
                })
                .unwrap();
                job += 1;
            }
        }
        let rounds = 120usize;
        let window = 6 * n; // generous: > n * ceil(max_tile/quantum) + n
        let mut last_admitted = vec![0u64; n];
        for round in 1..=rounds {
            let got = p.ingest();
            assert!(got.len() <= batch, "batch cap violated: {}", got.len());
            if round % window == 0 {
                let counters = p.tenant_counters();
                for t in 0..n {
                    let admitted = counters[t].1.admitted;
                    assert!(
                        admitted > last_admitted[t],
                        "tenant {t} (weight {}) starved through rounds {}..{round}",
                        weights[t],
                        round - window
                    );
                    last_admitted[t] = admitted;
                }
            }
        }
        // The backlog premise must still hold: no queue drained.
        for t in 0..n {
            assert!(p.queued_of(t) > 0, "tenant {t}'s backlog drained — premise broken");
        }
        let counters = p.tenant_counters();
        let total_tiles: u64 = counters.iter().map(|(_, c)| c.admitted_tiles).sum();
        let total_weight: u32 = weights.iter().sum();
        for t in 0..n {
            let share = counters[t].1.admitted_tiles as f64 / total_tiles as f64;
            let fair = weights[t] as f64 / total_weight as f64;
            assert!(
                share > 0.55 * fair && share < 1.45 * fair,
                "tenant {t}: admitted share {share:.3} vs weight share {fair:.3} \
                 (weights {weights:?})"
            );
        }
    });
}

#[test]
fn prop_fair_share_never_starves_a_tenant() {
    // The no-starvation acceptance property: random adversarial
    // streams-plus-shorts mixes, random weights and quotas, admission
    // pipeline armed, FairShare scheduling with preemption on — every
    // tenant's first service lands within a bounded window, every job
    // completes, and the checkpoint accounting balances.
    let catalog = Catalog::load_default().unwrap();
    cases(prop_cases(15), |rng| {
        let tenants = 2 + rng.below(4) as usize; // 2..=5
        let streamers = 1 + rng.below(tenants as u64 - 1) as usize; // 1..=tenants-1
        let stream_tiles = 150 + rng.below(150) as usize;
        let shorts = 4 + rng.below(6) as usize;
        let mut w = Workload::tenant_mix(tenants, streamers, stream_tiles, shorts, 2);
        for t in 0..tenants {
            let weight = 1 + rng.below(3) as u32;
            let quota = 2 + rng.below(6) as usize;
            w.set_qos(t, QosClass::new(weight, quota));
        }
        let cfg = SimConfig::new(
            if rng.bool(0.5) { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 },
            Policy::FairShare,
        )
        .with_admission(AdmissionConfig {
            quantum_tiles: 8,
            ..AdmissionConfig::default()
        });
        let r = simulate(&catalog, &w, &cfg);

        // Every job completes; preempt/resume accounting balances.
        assert!(r.job_completion.iter().all(|&t| t > 0), "a job never completed");
        assert_eq!(r.counters.preemptions, r.counters.resumes);
        // Bounded time-to-first-service for every tenant: a fully
        // starved FairShare tenant preempts after min_run_ns (10 ms)
        // at tick granularity, and starved tenants are served in
        // round-robin turn — so a generous per-tenant window bounds
        // everyone's first dispatch even on adversarial mixes.
        let bound = (tenants as u64) * 12 * PREEMPT_TICK_NS; // 60 ms per tenant
        for t in 0..tenants {
            let first = r
                .trace
                .iter()
                .filter(|e| e.user == t)
                .map(|e| e.start)
                .min()
                .expect("tenant never dispatched at all");
            assert!(
                first <= bound,
                "tenant {t} first served at {first} ns (bound {bound} ns; \
                 {tenants} tenants, {streamers} streamers)"
            );
        }
        // Per-tenant conservation: everything admitted completes.
        let admitted: u64 = r.per_tenant.iter().map(|(_, c)| c.admitted).sum();
        let completed: u64 = r.per_tenant.iter().map(|(_, c)| c.completed).sum();
        assert_eq!(admitted, w.total_requests() as u64);
        assert_eq!(completed, admitted);
    });
}

#[test]
fn prop_flash_crowd_busy_retries_conserve_per_tenant_counts() {
    // Scenario-engine flash crowds slammed into a tiny admission
    // queue_cap with a 1-deep in-flight quota: the spike forces
    // `Busy{retry_after}` deferrals while the weighted-DRR cursor wraps
    // across more tenants than one ingest batch serves — and every
    // deferral must drain back in without losing or duplicating a
    // single request, under seeded tie-break orderings on top.
    // Nightly runs this long via `FOS_PROPTEST_CASES`.
    let catalog = Catalog::load_default().unwrap();
    cases(prop_cases(8), |rng| {
        let seed = rng.next_u64();
        let tenants = 3 + rng.below(3) as usize; // 3..=5: cursor wraps past batch_cap
        let crowd = 24 + rng.below(17) as usize; // 24..=40 spike requests
        let sc = Scenario::flash_crowd(seed, tenants, 8, crowd, 10_000_000).with_inflight(1);
        let w = sc.to_workload();
        let cfg = ClusterSimConfig::new(
            vec![ShellBoard::Ultra96, ShellBoard::Zcu102],
            Policy::FairShare,
            PlacementKind::RoundRobin,
        )
        .with_admission(AdmissionConfig {
            queue_cap: 3,
            quantum_tiles: 2,
            batch_cap: 4,
            ..AdmissionConfig::default()
        })
        .with_order(OrderStrategy::Seeded(seed));
        let r = simulate_cluster(&catalog, &w, &cfg);
        // The premise: the crowd actually hit backpressure (a 1-deep
        // quota cannot drain a spike faster than it arrives).
        assert!(r.busy_retries > 0, "crowd of {crowd} never hit queue_cap 3");
        // Conservation per tenant through the retry storm + DRR wraps.
        let admitted: u64 = r.per_tenant.iter().map(|(_, tc)| tc.admitted).sum();
        assert_eq!(admitted, w.total_requests() as u64, "admission must be exact");
        for (t, tc) in &r.per_tenant {
            assert_eq!(tc.completed + tc.rejected, tc.admitted, "tenant {t} leaked");
            assert_eq!(tc.rejected, 0, "tenant {t}: Busy defers, it never loses");
        }
        assert!(r.job_completion.iter().all(|&t| t > 0), "a job never terminated");
    });
}

#[test]
fn prop_cluster_conserves_requests_under_any_placement() {
    // Random workloads over random heterogeneous clusters, any
    // placement policy: every request is routed exactly once and
    // dispatched exactly once on exactly one shard, every job
    // completes, and no shard's decisions escape its own fabric.
    let catalog = Catalog::load_default().unwrap();
    let accels = ["vadd", "fir", "dct", "sobel", "mandelbrot", "histogram"];
    let placements =
        [PlacementKind::RoundRobin, PlacementKind::LeastLoaded, PlacementKind::Locality];
    cases(prop_cases(15), |rng| {
        let n_boards = 1 + rng.below(4) as usize;
        let boards: Vec<ShellBoard> = (0..n_boards)
            .map(|_| if rng.bool(0.5) { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
            .collect();
        let mut w = Workload::new();
        let users = 1 + rng.below(5) as usize;
        for u in 0..users {
            let accel = *rng.pick(&accels);
            let tiles = 1 + rng.below(30) as usize;
            let reqs = 1 + rng.below(6) as usize;
            let arrival = rng.below(10_000_000);
            for j in JobSpec::frame(u, accel, arrival, tiles, reqs) {
                w.push(j);
            }
        }
        let placement = *rng.pick(&placements);
        let r = simulate_cluster(
            &catalog,
            &w,
            &ClusterSimConfig::new(boards.clone(), Policy::Elastic, placement),
        );

        assert_eq!(r.cluster.routed, w.total_requests() as u64);
        let placements_made: u64 =
            r.boards.iter().map(|b| b.counters.reconfigs + b.counters.reuses).sum();
        assert_eq!(placements_made, w.total_requests() as u64, "{placement:?}");
        assert_eq!(
            r.merged.len() as u64,
            placements_made,
            "merged log out of sync with per-shard placements"
        );
        for (b, board) in r.boards.iter().enumerate() {
            let regions = if boards[b] == ShellBoard::Ultra96 { 3 } else { 4 };
            for d in &board.decisions {
                assert!(d.anchor + d.span <= regions, "board {b}: {d:?}");
            }
        }
        for (j, &done) in r.job_completion.iter().enumerate() {
            assert!(done >= w.jobs[j].arrival, "job {j} completed before arrival");
            assert!(done <= r.makespan);
        }
    });
}
