//! Allocation guard for the log tail queries: tailing a 100k-entry
//! decision log (and a cluster merged log) must perform **zero** heap
//! allocations.  Decisions carry interned symbols — no heap fields —
//! so a tail query is pure pointer iteration over the ring buffer;
//! this test pins that property with a counting global allocator.
//!
//! The counter is armed per-thread (a thread-local flag) so libtest's
//! own threads cannot pollute the window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

use fos::accel::Catalog;
use fos::sched::{ClusterCore, PlacementKind, Policy, SchedCore};
use fos::shell::{Shell, ShellBoard};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

thread_local! {
    static ARMED: Cell<bool> = const { Cell::new(false) };
}

struct CountingAlloc;

// SAFETY: delegates every operation to `System` unchanged; the only
// addition is a counter bump, which allocates nothing (the armed flag
// is a const-initialised `Cell<bool>`, so the TLS access itself never
// allocates, and `try_with` covers teardown).
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

fn bump() {
    if ARMED.try_with(Cell::get).unwrap_or(false) {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Run `f` with the counter armed on this thread; returns how many
/// allocations happened inside the window.
fn allocations_in(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.load(Ordering::Relaxed);
    ARMED.with(|a| a.set(true));
    f();
    ARMED.with(|a| a.set(false));
    ALLOCS.load(Ordering::Relaxed) - before
}

const LOG: usize = 100_000;

#[test]
fn log_tail_queries_do_zero_heap_allocations() {
    let catalog = Catalog::load_default().unwrap();

    // --- single core: fill a 100k-entry ring log ------------------
    let shell = Shell::build(ShellBoard::Ultra96);
    let mut core = SchedCore::new(&shell, catalog.clone(), Policy::Elastic);
    core.set_log_cap(LOG);
    for j in 0..LOG as u64 {
        core.submit(0, j, "vadd", 1, None).unwrap();
        core.begin_round();
        let d = core.next_decision().expect("vadd must place on an idle fabric");
        core.complete(d.anchor);
    }
    assert_eq!(core.decision_log().count(), LOG, "log must be full before the query");

    let allocs = allocations_in(|| {
        let mut acc = 0usize;
        for d in core.decision_log_tail(LOG) {
            acc += d.anchor + d.span + d.tiles + d.accel.index() + d.variant.index();
        }
        std::hint::black_box(acc);
    });
    assert_eq!(allocs, 0, "decision_log_tail over {LOG} entries allocated {allocs} times");

    // --- cluster: the merged tagged log ---------------------------
    let mut cluster = ClusterCore::new(
        &[ShellBoard::Ultra96, ShellBoard::Zcu102],
        &catalog,
        Policy::Elastic,
        PlacementKind::RoundRobin,
    );
    for j in 0..512u64 {
        let b = cluster.submit(0, j, "vadd", 1, None).unwrap();
        cluster.begin_round_at(b, 0);
        while let Some(d) = cluster.next_decision(b) {
            cluster.complete(b, d.anchor);
        }
    }
    let merged = cluster.merged_log().count();
    assert!(merged >= 512, "cluster drive must populate the merged log ({merged})");

    let allocs = allocations_in(|| {
        let mut acc = 0usize;
        for (b, d) in cluster.merged_log_tail(merged) {
            acc += b + d.anchor + d.accel.index();
        }
        std::hint::black_box(acc);
    });
    assert_eq!(allocs, 0, "merged_log_tail over {merged} entries allocated {allocs} times");
}
