//! End-to-end: the full multi-tenant stack over a real Unix socket with
//! real PJRT compute, and the DES scheduler with real compute attached
//! (policy changes must never change results).

use fos::accel::Catalog;
use fos::daemon::{Daemon, FpgaRpc, Job, SharedMem};
use fos::runtime::Executor;
use fos::sched::{simulate, JobSpec, Policy, SimConfig, Workload};
use fos::shell::ShellBoard;

fn sock(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("fos_e2e_{name}_{}.sock", std::process::id()))
}

#[test]
fn daemon_three_tenants_mixed_accelerators() {
    if !fos::testutil::pjrt_available() {
        eprintln!("skipping: PJRT backend unavailable (offline stub)");
        return;
    }
    let path = sock("mixed");
    let catalog = Catalog::load_default().unwrap();
    let daemon = Daemon::start(&path, ShellBoard::Ultra96, catalog).unwrap();

    let mk_worker = |accel: &'static str, in_reg: &'static str, out_reg: &'static str,
                     in_elems: usize, out_elems: usize| {
        let path = path.clone();
        std::thread::spawn(move || {
            let mut rpc = FpgaRpc::connect(&path).unwrap();
            let input = rpc.alloc(4 * in_elems).unwrap();
            let output = rpc.alloc(4 * out_elems).unwrap();
            let data: Vec<f32> = (0..in_elems).map(|i| (i % 251) as f32 / 251.0).collect();
            rpc.write_f32(input, &data).unwrap();
            let jobs: Vec<Job> = (0..3)
                .map(|_| Job::new(accel, vec![(in_reg.into(), input), (out_reg.into(), output)]))
                .collect();
            let report = rpc.run(&jobs).unwrap();
            assert_eq!(report.latencies_us.len(), 3);
            rpc.read_f32(output, out_elems).unwrap()
        })
    };

    let t1 = mk_worker("sobel", "in_img", "out_img", 128 * 128, 128 * 128);
    let t2 = mk_worker("histogram", "x_op", "h_out", 4096, 256);
    let t3 = mk_worker("aes", "in_data", "out_data", 4096, 4096);
    let sobel_out = t1.join().unwrap();
    let hist_out = t2.join().unwrap();
    let aes_out = t3.join().unwrap();

    assert!(sobel_out.iter().all(|v| v.is_finite()));
    // Histogram conservation: 4096 samples in [0,1).
    assert_eq!(hist_out.iter().sum::<f32>(), 4096.0);
    assert_eq!(aes_out.len(), 4096);

    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(daemon.stats().jobs.load(Relaxed), 9);
    // Three different accelerators on a 3-region fabric: loads + reuses.
    assert!(daemon.stats().reconfig_loads.load(Relaxed) >= 3);
}

#[test]
fn shm_roundtrip_matches_socket_path() {
    if !fos::testutil::pjrt_available() {
        eprintln!("skipping: PJRT backend unavailable (offline stub)");
        return;
    }
    let path = sock("shm2");
    let catalog = Catalog::load_default().unwrap();
    let _daemon = Daemon::start(&path, ShellBoard::Ultra96, catalog).unwrap();
    let mut rpc = FpgaRpc::connect(&path).unwrap();

    let n = 4096;
    let data: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
    let a = rpc.alloc(4 * n).unwrap();
    let b = rpc.alloc(4 * n).unwrap();
    let c = rpc.alloc(4 * n).unwrap();
    // Socket path for a, shm path for b.
    rpc.write_f32(a, &data).unwrap();
    let shm_file = std::env::temp_dir().join(format!("fos_e2e_shm_{}.bin", std::process::id()));
    let mut shm = SharedMem::create(&shm_file, 4 * n).unwrap();
    shm.write_f32(0, &data).unwrap();
    rpc.import_shm(&shm.path, 0, n, b).unwrap();

    let job = Job::new(
        "vadd",
        vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
    );
    rpc.run(&[job]).unwrap();
    let out = rpc.read_f32(c, n).unwrap();
    for (k, v) in out.iter().enumerate() {
        assert!((v - 2.0 * data[k]).abs() < 1e-5);
    }
}

#[test]
fn policies_compute_identical_results() {
    if !fos::testutil::pjrt_available() {
        eprintln!("skipping: PJRT backend unavailable (offline stub)");
        return;
    }
    // Virtual-time policy choice must not affect numerics: checksum of
    // all real outputs is identical across Elastic and Fixed.
    let catalog = Catalog::load_default().unwrap();
    let mut w = Workload::new();
    for j in JobSpec::frame(0, "dct", 0, 4, 2) {
        w.push(j);
    }
    for j in JobSpec::frame(1, "vadd", 0, 4, 2) {
        w.push(j);
    }
    let run = |policy| {
        let mut cfg = SimConfig::new(ShellBoard::Ultra96, policy);
        cfg.executor = Some(Executor::new(Catalog::load_default().unwrap()));
        let r = simulate(&catalog, &w, &cfg);
        assert_eq!(r.tiles_executed, 8);
        r.output_checksum
    };
    assert_eq!(run(Policy::Elastic), run(Policy::Fixed));
}

#[test]
fn virtual_time_independent_of_real_compute() {
    if !fos::testutil::pjrt_available() {
        eprintln!("skipping: PJRT backend unavailable (offline stub)");
        return;
    }
    // Attaching the executor must not change the modelled makespan.
    let catalog = Catalog::load_default().unwrap();
    let mut w = Workload::new();
    for j in JobSpec::frame(0, "vadd", 0, 4, 2) {
        w.push(j);
    }
    let plain = simulate(&catalog, &w, &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic));
    let mut cfg = SimConfig::new(ShellBoard::Ultra96, Policy::Elastic);
    cfg.executor = Some(Executor::new(Catalog::load_default().unwrap()));
    let real = simulate(&catalog, &w, &cfg);
    assert_eq!(plain.makespan, real.makespan);
    assert_eq!(real.tiles_executed, 4);
    assert_ne!(real.output_checksum, plain.output_checksum); // plain = seed only
}
