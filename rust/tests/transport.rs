//! Framing-layer integration tests against a live daemon socket.
//!
//! The reactor transport (`src/daemon/transport.rs`) reassembles
//! `[u32 LE length][JSON body]` frames from whatever byte boundaries
//! the kernel delivers, rejects frames that violate the protocol by
//! silently closing the connection (see `src/daemon/PROTOCOL.md` §6),
//! and flushes replies under write backpressure without buffering more
//! than one in-flight reply per connection.  Each test drives those
//! paths over a real `UnixStream` — no test-only hooks into the
//! reactor.

use fos::accel::Catalog;
use fos::daemon::{read_msg, write_msg, Daemon, DaemonConfig, FpgaRpc, MAX_MSG};
use fos::json::{i, obj, s, Value};
use fos::shell::ShellBoard;
use std::collections::HashSet;
use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::time::Duration;

fn sock(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fos_transport_{name}_{}.sock", std::process::id()))
}

fn start(name: &str) -> (Daemon, PathBuf) {
    let path = sock(name);
    let d = Daemon::start(&path, ShellBoard::Ultra96, Catalog::load_default().unwrap()).unwrap();
    (d, path)
}

/// A daemon whose network plane runs `shards` reactor shards behind
/// the dedicated acceptor (the `--reactor-shards N` topology).
fn start_sharded(name: &str, shards: usize) -> (Daemon, PathBuf) {
    let path = sock(name);
    let cfg = DaemonConfig::new(&[ShellBoard::Ultra96], Catalog::load_default().unwrap())
        .reactor_shards(shards);
    let d = Daemon::start_configured(&path, cfg).unwrap();
    (d, path)
}

fn ping_frame() -> Vec<u8> {
    let mut buf = Vec::new();
    write_msg(&mut buf, &obj(vec![("method", s("ping"))])).unwrap();
    buf
}

fn connect(path: &PathBuf) -> UnixStream {
    let c = UnixStream::connect(path).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    c
}

/// Read until EOF (or fail the test if the server keeps the
/// connection open past the read timeout).
fn expect_eof(c: &mut UnixStream) {
    let mut buf = [0u8; 256];
    loop {
        match c.read(&mut buf) {
            Ok(0) => return,
            Ok(_) => continue, // drain whatever was already queued
            Err(e) => panic!("expected server-side close, got read error: {e}"),
        }
    }
}

#[test]
fn dribbled_ping_reassembles_across_every_boundary() {
    let (_d, path) = start("dribble");
    let mut c = connect(&path);
    // One byte per write: the header itself arrives in four separate
    // reads, the body in as many more — every partial-read branch of
    // the frame assembler fires.
    for b in ping_frame() {
        c.write_all(&[b]).unwrap();
        c.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let reply = read_msg(&mut c).unwrap();
    assert_eq!(reply.get("status").as_str(), Some("ok"));
}

#[test]
fn pipelined_pings_split_at_odd_boundaries() {
    let (_d, path) = start("pipeline");
    let mut c = connect(&path);
    // Three frames back-to-back, delivered in 7-byte slices so every
    // chunk straddles a header or frame boundary.  The reactor parses
    // one frame per round trip (strict write-one-read-one) and leaves
    // the rest buffered; replies must come back in order.
    let mut wire = Vec::new();
    for _ in 0..3 {
        wire.extend_from_slice(&ping_frame());
    }
    for chunk in wire.chunks(7) {
        c.write_all(chunk).unwrap();
        c.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    for _ in 0..3 {
        let reply = read_msg(&mut c).unwrap();
        assert_eq!(reply.get("status").as_str(), Some("ok"));
    }
}

#[test]
fn oversized_frame_header_closes_the_connection() {
    let (_d, path) = start("oversized");
    let mut c = connect(&path);
    // A header announcing a body past MAX_MSG is a protocol violation:
    // the server closes without a reply rather than reserving 64 MiB+.
    c.write_all(&(MAX_MSG + 1).to_le_bytes()).unwrap();
    // The connection may already be gone; any trailing write error is
    // part of the expected close.
    let _ = c.write_all(b"xxxx");
    expect_eof(&mut c);
    // The daemon itself is unaffected: a fresh connection still works.
    let mut c2 = connect(&path);
    c2.write_all(&ping_frame()).unwrap();
    assert_eq!(read_msg(&mut c2).unwrap().get("status").as_str(), Some("ok"));
}

#[test]
fn malformed_json_body_closes_the_connection() {
    let (_d, path) = start("malformed");
    let mut c = connect(&path);
    let body = b"not json at all";
    c.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    c.write_all(body).unwrap();
    expect_eof(&mut c);
    let mut c2 = connect(&path);
    c2.write_all(&ping_frame()).unwrap();
    assert_eq!(read_msg(&mut c2).unwrap().get("status").as_str(), Some("ok"));
}

#[test]
fn missing_method_is_an_error_reply_not_a_close() {
    // Contrast with the framing violations above: a well-framed frame
    // with an unknown method gets a structured err reply and the
    // connection survives (PROTOCOL.md §3).
    let (_d, path) = start("unknown");
    let mut c = connect(&path);
    write_msg(&mut c, &obj(vec![("method", s("no-such-rpc"))])).unwrap();
    let reply = read_msg(&mut c).unwrap();
    assert_eq!(reply.get("status").as_str(), Some("err"));
    write_msg(&mut c, &obj(vec![("method", s("ping"))])).unwrap();
    assert_eq!(read_msg(&mut c).unwrap().get("status").as_str(), Some("ok"));
}

#[test]
fn slow_reader_backpressure_stalls_one_connection_not_the_reactor() {
    let (_d, path) = start("backpressure");

    // Stage 1 MiB of device memory through the normal client.  Both
    // connections bind the same named tenant so the raw reader shares
    // the setup connection's isolation domain (per-connection anonymous
    // tenants would otherwise deny the cross-connection read).
    let mut setup = FpgaRpc::connect(&path).unwrap();
    setup.set_session("bp-tenant", None, 1, 0).unwrap();
    let n_floats = (1usize << 20) / 4;
    let handle = setup.alloc(1 << 20).unwrap();
    let xs: Vec<f32> = (0..n_floats).map(|v| v as f32).collect();
    setup.write_f32(handle, &xs).unwrap();

    // Ask for all of it on a raw connection and then refuse to read:
    // the ~1.4 MB base64 reply overflows the socket buffer, so the
    // reactor must park the remainder in the connection's write buffer
    // and wait for writability instead of blocking the event loop.
    let mut slow = connect(&path);
    let bind = obj(vec![("method", s("session")), ("tenant", s("bp-tenant"))]);
    write_msg(&mut slow, &bind).unwrap();
    assert_eq!(read_msg(&mut slow).unwrap().get("status").as_str(), Some("ok"));
    let req = obj(vec![
        ("method", s("read")),
        ("handle", i(handle.raw() as i64)),
        ("count", i(n_floats as i64)),
    ]);
    write_msg(&mut slow, &req).unwrap();
    std::thread::sleep(Duration::from_millis(300));

    // While the slow reader stalls, the reactor still serves others.
    let mut probe = FpgaRpc::connect(&path).unwrap();
    let rtt = probe.ping().unwrap();
    assert!(rtt < Duration::from_secs(2), "reactor blocked behind a slow reader: {rtt:?}");

    // Drain the stalled reply: complete, valid, correct payload size.
    let expect_b64 = |reply: Value| {
        assert_eq!(reply.get("status").as_str(), Some("ok"));
        let b64 = reply.get("b64").as_str().expect("read reply missing b64").to_string();
        assert_eq!(b64.len(), (1usize << 20).div_ceil(3) * 4);
    };
    expect_b64(read_msg(&mut slow).unwrap());

    // The connection survives backpressure: the same request round-
    // trips again after the write buffer drained (and shrank).
    write_msg(&mut slow, &req).unwrap();
    expect_b64(read_msg(&mut slow).unwrap());
}

// ---- multi-shard reactor plane (--reactor-shards N) -------------------

#[test]
fn cross_shard_replies_route_to_the_owning_connection_under_pipelined_load() {
    // 16 connections dealt round-robin across 4 shards, each
    // pipelining bursts of pings.  Every ping reply carries the
    // connection's daemon `user` id, so a reply mis-routed to a
    // different shard's slab slot (or a different connection's slot)
    // shows up as a user-id mismatch, not just a hang.
    let (_d, path) = start_sharded("xshard_route", 4);
    let mut conns: Vec<UnixStream> = (0..16).map(|_| connect(&path)).collect();
    let mut users: Vec<Option<i64>> = vec![None; conns.len()];
    for _round in 0..3 {
        // Pipeline a burst on every connection before reading any
        // reply, so all shards hold in-flight traffic at once.
        for c in conns.iter_mut() {
            for _ in 0..4 {
                c.write_all(&ping_frame()).unwrap();
            }
        }
        for (k, c) in conns.iter_mut().enumerate() {
            for _ in 0..4 {
                let reply = read_msg(c).unwrap();
                assert_eq!(reply.get("status").as_str(), Some("ok"));
                let user = reply.get("user").as_i64().expect("ping reply carries user");
                match users[k] {
                    None => users[k] = Some(user),
                    Some(u) => {
                        assert_eq!(u, user, "reply for user {user} routed to connection of {u}")
                    }
                }
            }
        }
    }
    // 16 connections across 4 shards must have minted 16 distinct ids.
    let distinct: HashSet<i64> = users.iter().map(|u| u.unwrap()).collect();
    assert_eq!(distinct.len(), conns.len());
}

#[test]
fn shard_tokens_and_users_stay_unique_after_slot_recycling() {
    // Connect a wave on every shard, drop it (recycling every slab
    // slot), connect another wave.  The shard tag + epoch in the slab
    // key and the strided user counter must keep daemon user ids
    // globally unique across shards AND across recycled slots — a
    // collision would alias two connections' scheduler state.
    let (_d, path) = start_sharded("xshard_unique", 3);
    let mut seen: HashSet<i64> = HashSet::new();
    for _wave in 0..2 {
        let mut conns: Vec<UnixStream> = (0..9).map(|_| connect(&path)).collect();
        for c in conns.iter_mut() {
            c.write_all(&ping_frame()).unwrap();
            let reply = read_msg(c).unwrap();
            assert_eq!(reply.get("status").as_str(), Some("ok"));
            let user = reply.get("user").as_i64().expect("ping reply carries user");
            assert!(seen.insert(user), "user id {user} reissued after slot recycling");
        }
        // Dropping the wave recycles all nine slots on their shards.
    }
    assert_eq!(seen.len(), 18);
}

#[test]
fn slow_reader_on_one_shard_does_not_stall_another_shard() {
    // Two shards, connections dealt round-robin: the setup client
    // lands on shard 0, the deliberately-stalled reader on shard 1,
    // the probe back on shard 0.  The stalled connection parks ~1.4 MB
    // of reply in ITS shard's write buffer; the probe's shard must
    // keep answering at full speed.
    let (_d, path) = start_sharded("xshard_bp", 2);

    let mut setup = FpgaRpc::connect(&path).unwrap();
    setup.set_session("bp-tenant", None, 1, 0).unwrap();
    let n_floats = (1usize << 20) / 4;
    let handle = setup.alloc(1 << 20).unwrap();
    let xs: Vec<f32> = (0..n_floats).map(|v| v as f32).collect();
    setup.write_f32(handle, &xs).unwrap();

    let mut slow = connect(&path);
    let bind = obj(vec![("method", s("session")), ("tenant", s("bp-tenant"))]);
    write_msg(&mut slow, &bind).unwrap();
    assert_eq!(read_msg(&mut slow).unwrap().get("status").as_str(), Some("ok"));
    let req = obj(vec![
        ("method", s("read")),
        ("handle", i(handle.raw() as i64)),
        ("count", i(n_floats as i64)),
    ]);
    write_msg(&mut slow, &req).unwrap();
    std::thread::sleep(Duration::from_millis(300));

    // While shard 1's reader stalls, a connection on the other shard
    // still round-trips promptly.
    let mut probe = FpgaRpc::connect(&path).unwrap();
    let rtt = probe.ping().unwrap();
    assert!(rtt < Duration::from_secs(2), "other shard blocked behind a slow reader: {rtt:?}");

    // The stalled reply is still complete and correct once drained.
    let reply = read_msg(&mut slow).unwrap();
    assert_eq!(reply.get("status").as_str(), Some("ok"));
    let b64 = reply.get("b64").as_str().expect("read reply missing b64");
    assert_eq!(b64.len(), (1usize << 20).div_ceil(3) * 4);
}

#[test]
fn shutdown_drains_every_shard_cleanly() {
    // Live connections on all four shards when the daemon stops: every
    // client must observe a clean server-side close (EOF, not a reset
    // or a hang), and shutdown itself must join all shard threads plus
    // the acceptor (a leaked thread would hang the test binary).
    let (mut d, path) = start_sharded("xshard_shutdown", 4);
    let mut conns: Vec<UnixStream> = (0..8).map(|_| connect(&path)).collect();
    for c in conns.iter_mut() {
        c.write_all(&ping_frame()).unwrap();
        assert_eq!(read_msg(c).unwrap().get("status").as_str(), Some("ok"));
    }
    d.shutdown();
    for c in conns.iter_mut() {
        expect_eof(c);
    }
}
