//! Concurrency-fuzzing DES sweeps: seeded [`fos::sched::OrderStrategy`]
//! permutations of every legal event interleaving — equal-timestamp
//! tie-breaks, admission ingest-batch order, preemption-tick jitter —
//! driven over scenario-engine traces, asserting the invariants that
//! must survive ANY legal ordering:
//!
//! - **Conservation** — per tenant, `admitted == completed + rejected`
//!   and, with no fault plan armed, zero lost work (`rejected == 0`,
//!   every job terminates).
//! - **Identity default** — `OrderStrategy::Identity` is byte-identical
//!   to today's FIFO order (the golden-fixture gate below, plus the
//!   untouched `golden_decisions` / `sched_parity` / `cluster_parity`
//!   suites).
//! - **Sim/daemon parity** — a scenario replayed through
//!   `simulate_cluster` and through a live scenario-armed daemon
//!   (`fos daemon --scenario`) yields the same decision-key sequence,
//!   identity and seeded strategies alike.
//!
//! Every sweep obeys `FOS_FUZZ_SEEDS` (default 8 — the tier-1 smoke
//! gate; nightly runs ≥ 64) and honours a `FOS_SCENARIO` spec override
//! so any failing case replays from the one-line repro this harness
//! prints (and writes to `FOS_FUZZ_REPRO_DIR` for the nightly artifact
//! upload):
//!
//! ```text
//! FOS_FUZZ_SEEDS=<s+1> FOS_SCENARIO='<spec>' cargo test --test fuzz_orderings <name>
//! ```

use fos::accel::Catalog;
use fos::daemon::{Daemon, DaemonConfig};
use fos::sched::{
    simulate_cluster, AdmissionConfig, ClusterSimConfig, Decision, DecisionKind, OrderStrategy,
    PlacementKind, Policy, Scenario, Sym, SymbolTable,
};
use fos::shell::ShellBoard;
use std::path::PathBuf;

const FIXTURE: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/golden_scenario.txt");

/// (kind, accel, variant, anchor, span, reconfigure, replicated, tiles)
/// — the cross-harness decision key (`tests/cluster_parity.rs`): job
/// tokens differ between sim indices and daemon tokens, everything the
/// scheduler actually decided is in here.
type Key = (DecisionKind, Sym, Sym, usize, usize, bool, bool, usize);

fn key(d: &Decision) -> Key {
    (d.kind, d.accel, d.variant, d.anchor, d.span, d.reconfigure, d.replicated, d.tiles)
}

fn catalog() -> Catalog {
    Catalog::load_default().unwrap()
}

fn boards(n: usize) -> Vec<ShellBoard> {
    (0..n)
        .map(|i| if i % 2 == 0 { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
        .collect()
}

fn sock(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fos_fuzz_{name}_{}.sock", std::process::id()))
}

/// Seeded orderings swept per property: `FOS_FUZZ_SEEDS` (nightly
/// ≥ 64), defaulting to the tier-1 smoke width.
fn fuzz_seeds() -> u64 {
    std::env::var("FOS_FUZZ_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(8)
}

/// Seed-derived scenario, rotating over all four generators so the
/// sweep covers diurnal thinning, correlated bursts, flash crowds and
/// heavy-tailed sizing.  A `FOS_SCENARIO` spec overrides every seed —
/// that is what makes the printed repro line replay the exact trace.
fn fuzz_scenario(seed: u64) -> Scenario {
    if let Ok(spec) = std::env::var("FOS_SCENARIO") {
        if !spec.is_empty() {
            return Scenario::parse(&spec).expect("FOS_SCENARIO must parse");
        }
    }
    match seed % 4 {
        0 => Scenario::diurnal(seed, 4, 20, 16_000_000),
        1 => Scenario::bursts(seed, 3, 3, 6, 16_000_000),
        2 => Scenario::flash_crowd(seed, 4, 8, 12, 16_000_000),
        _ => Scenario::heavy_tailed(seed, 3, 16, 16_000_000),
    }
}

/// Write a failure repro (seed + scenario spec + rerun line) for the
/// nightly artifact upload; no-op unless `FOS_FUZZ_REPRO_DIR` is set.
fn write_repro(name: &str, seed: u64, scenario: &Scenario, detail: &str) {
    let Ok(dir) = std::env::var("FOS_FUZZ_REPRO_DIR") else { return };
    let _ = std::fs::create_dir_all(&dir);
    let path = std::path::Path::new(&dir).join(format!("{name}_seed_{seed}.txt"));
    let _ = std::fs::write(
        &path,
        format!(
            "test: {name}\nseed: {seed}\nscenario: {}\ndetail: {detail}\n\
             rerun: FOS_FUZZ_SEEDS={} FOS_SCENARIO='{}' cargo test --test fuzz_orderings {name}\n",
            scenario.to_spec(),
            seed + 1,
            scenario.to_spec(),
        ),
    );
}

/// Run one seeded case under `catch_unwind`; on failure, persist the
/// repro artifact and print the one-line rerun command before
/// re-raising.
fn seeded_case(name: &str, seed: u64, scenario: &Scenario, case: impl FnOnce()) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(case));
    if let Err(e) = result {
        write_repro(name, seed, scenario, "assertion failed (see test log)");
        eprintln!(
            "fuzz {name} failed at ordering seed {seed}; \
             repro: FOS_FUZZ_SEEDS={} FOS_SCENARIO='{}' cargo test --test fuzz_orderings {name}",
            seed + 1,
            scenario.to_spec(),
        );
        std::panic::resume_unwind(e);
    }
}

/// Conservation under every seeded ordering × policy × placement: no
/// permutation of equal-time ties, no ingest shuffle, no tick jitter
/// may lose or duplicate a request.
#[test]
fn fuzz_orderings_conserve_per_tenant_counts() {
    let c = catalog();
    for seed in 0..fuzz_seeds() {
        let sc = fuzz_scenario(seed);
        let w = sc.to_workload();
        for policy in [Policy::Elastic, Policy::FairShare] {
            for placement in [PlacementKind::RoundRobin, PlacementKind::Locality] {
                seeded_case("conservation", seed, &sc, || {
                    let cfg = ClusterSimConfig::new(boards(2), policy, placement)
                        .with_order(OrderStrategy::Seeded(seed));
                    let r = simulate_cluster(&c, &w, &cfg);
                    let admitted: u64 =
                        r.per_tenant.iter().map(|(_, tc)| tc.admitted).sum();
                    assert_eq!(
                        admitted,
                        w.total_requests() as u64,
                        "admission must be exact ({policy:?}/{placement:?})"
                    );
                    // Per tenant — not just in aggregate — every
                    // admitted request ends exactly one way, and with
                    // no faults armed the only way is completion.
                    for (t, tc) in &r.per_tenant {
                        assert_eq!(
                            tc.completed + tc.rejected,
                            tc.admitted,
                            "tenant {t} leaks under {policy:?}/{placement:?}"
                        );
                        assert_eq!(
                            tc.rejected, 0,
                            "tenant {t}: zero lost work without faults"
                        );
                    }
                    assert!(
                        r.job_completion.iter().all(|&t| t > 0),
                        "a job never terminated ({policy:?}/{placement:?})"
                    );
                });
            }
        }
    }
}

/// `OrderStrategy::Identity` must be indistinguishable from not
/// configuring an ordering at all — same merged decision sequence,
/// same makespan, byte for byte.
#[test]
fn identity_strategy_matches_default_exactly() {
    let c = catalog();
    let sc = fuzz_scenario(0);
    let w = sc.to_workload();
    let base = ClusterSimConfig::new(boards(2), Policy::Elastic, PlacementKind::Locality);
    let plain = simulate_cluster(&c, &w, &base);
    let cfg = ClusterSimConfig::new(boards(2), Policy::Elastic, PlacementKind::Locality)
        .with_order(OrderStrategy::Identity);
    let ident = simulate_cluster(&c, &w, &cfg);
    let a: Vec<(usize, Key)> = plain.merged.iter().map(|(b, d)| (*b, key(d))).collect();
    let b: Vec<(usize, Key)> = ident.merged.iter().map(|(b, d)| (*b, key(d))).collect();
    assert_eq!(a, b, "identity strategy perturbed the decision sequence");
    assert_eq!(plain.makespan, ident.makespan, "identity strategy perturbed time");
}

/// Seeded orderings are (a) deterministic — the same seed replays the
/// same sequence — and (b) actually explore the tie-break space: over
/// the sweep at least one seed reorders a scenario built from
/// equal-timestamp arrivals.
#[test]
fn seeded_orderings_are_deterministic_and_explore_ties() {
    let c = catalog();
    // Six arrivals sharing one timestamp across three tenants: the
    // equal-time batch and the ingest batch both have real ties.
    let spec = "v=1,seed=0,\
                at=1@t0w1:sobel/sobel_v1x2*1,at=1@t1w1:dctx3*1,at=1@t2w1:firx1*1,\
                at=1@t0w1:vaddx2*1,at=1@t1w1:sobelx1*1,at=1@t2w1:dct/dct_v1x2*1";
    let sc = Scenario::parse(spec).unwrap();
    let w = sc.to_workload();
    let run = |order: OrderStrategy| {
        let cfg = ClusterSimConfig::new(boards(2), Policy::Elastic, PlacementKind::RoundRobin)
            .with_order(order);
        let r = simulate_cluster(&c, &w, &cfg);
        r.merged.iter().map(|(b, d)| (*b, key(d))).collect::<Vec<_>>()
    };
    let identity = run(OrderStrategy::Identity);
    let mut reordered = false;
    for seed in 0..fuzz_seeds() {
        seeded_case("determinism", seed, &sc, || {
            let once = run(OrderStrategy::Seeded(seed));
            let twice = run(OrderStrategy::Seeded(seed));
            assert_eq!(once, twice, "seed {seed} is not deterministic");
            assert_eq!(once.len(), identity.len(), "seed {seed} changed decision count");
        });
        if run(OrderStrategy::Seeded(seed)) != identity {
            reordered = true;
        }
    }
    assert!(
        reordered,
        "no seed in the sweep reordered an all-ties batch — the permutation hooks are dead"
    );
}

/// The canonical diurnal trace through the cluster sim under the
/// identity strategy, pinned byte-for-byte against a committed golden
/// fixture — the scenario engine's replay gate.  Regenerate
/// deliberately with `FOS_UPDATE_GOLDEN=1 cargo test --test
/// fuzz_orderings` (`scripts/arm_bench_baselines.sh` does this).
#[test]
fn golden_scenario_fixture_matches() {
    let c = catalog();
    let sc = Scenario::diurnal(7, 4, 48, 40_000_000);
    let w = sc.to_workload();
    let symbols = SymbolTable::from_catalog(&c);
    let r = simulate_cluster(
        &c,
        &w,
        &ClusterSimConfig::new(boards(2), Policy::Elastic, PlacementKind::Locality),
    );
    let mut got = format!("== scenario diurnal identity ==\nspec: {}\n", sc.to_spec());
    for (b, d) in &r.merged {
        got.push_str(&format!(
            "{} {:?} {} {}\n",
            b,
            d.kind,
            symbols.resolve(d.accel),
            d.anchor
        ));
    }
    if std::env::var("FOS_UPDATE_GOLDEN").is_ok_and(|v| !v.is_empty() && v != "0") {
        std::fs::create_dir_all(std::path::Path::new(FIXTURE).parent().unwrap()).unwrap();
        std::fs::write(FIXTURE, &got).unwrap();
        eprintln!("golden scenario fixture rewritten: {FIXTURE}");
        return;
    }
    let want = match std::fs::read_to_string(FIXTURE) {
        Ok(w) => w,
        Err(_) => {
            // Bootstrap on first toolchain run (the repo's golden
            // pattern): arm the fixture from the deterministic sim
            // output and commit it to pin the sequence.
            std::fs::create_dir_all(std::path::Path::new(FIXTURE).parent().unwrap()).unwrap();
            std::fs::write(FIXTURE, &got).unwrap();
            eprintln!("golden scenario fixture bootstrapped: {FIXTURE} — commit it");
            return;
        }
    };
    if got != want {
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(g, w, "first divergence at fixture line {}", i + 1);
        }
        assert_eq!(got.lines().count(), want.lines().count(), "sequence length changed");
        unreachable!("sequences differ but no divergent line found");
    }
}

/// A scenario spec survives `to_spec -> parse` ns-exactly and the
/// re-parsed trace replays to the identical decision sequence.
#[test]
fn scenario_spec_roundtrip_replays_identically() {
    let c = catalog();
    for seed in 0..fuzz_seeds().min(4) {
        let sc = fuzz_scenario(seed);
        seeded_case("roundtrip", seed, &sc, || {
            let back = Scenario::parse(&sc.to_spec()).unwrap();
            assert_eq!(back, sc, "spec round-trip must be ns-exact");
            let cfg =
                ClusterSimConfig::new(boards(2), Policy::Elastic, PlacementKind::Locality);
            let a = simulate_cluster(&c, &sc.to_workload(), &cfg);
            let b = simulate_cluster(&c, &back.to_workload(), &cfg);
            let ka: Vec<(usize, Key)> = a.merged.iter().map(|(b, d)| (*b, key(d))).collect();
            let kb: Vec<(usize, Key)> = b.merged.iter().map(|(b, d)| (*b, key(d))).collect();
            assert_eq!(ka, kb, "re-parsed spec replayed differently");
            assert_eq!(a.makespan, b.makespan);
        });
    }
}

/// Replay one scenario through a live scenario-armed daemon and wait
/// for the full decision sequence, then return its keys.
fn daemon_replay(name: &str, sc: &Scenario, order: OrderStrategy, expect: usize) -> Vec<Key> {
    let path = sock(name);
    let cfg = DaemonConfig::new(&[ShellBoard::Ultra96, ShellBoard::Zcu102], catalog())
        .scenario(sc.clone())
        .order(order);
    let daemon = Daemon::start_configured(&path, cfg).unwrap();
    // The replay runs on the dispatcher's virtual clock — fast, but
    // still on its own thread: poll until the decision log catches the
    // simulator's length (a diverging daemon is caught by the key
    // comparison, not the poll).
    for _ in 0..5000 {
        if daemon.merged_decision_log().len() >= expect {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    daemon.merged_decision_log().iter().map(|(_, d)| key(d)).collect()
}

/// Sim/daemon decision-key parity under scenario replay: the same
/// trace lowered through `simulate_cluster` and through a live
/// `--scenario`-armed daemon produces the same decision-key sequence —
/// under the identity strategy AND under a seeded permutation (both
/// harnesses resolve the same ties with the same seeded choices).
#[test]
fn scenario_replays_identically_through_live_daemon() {
    let c = catalog();
    let sc = Scenario::diurnal(11, 3, 14, 8_000_000);
    let w = sc.to_workload();
    for (tag, order) in
        [("identity", OrderStrategy::Identity), ("seeded", OrderStrategy::Seeded(5))]
    {
        seeded_case("daemon_parity", 5, &sc, || {
            let cfg =
                ClusterSimConfig::new(boards(2), Policy::Elastic, PlacementKind::Locality)
                    .with_order(order);
            let sim = simulate_cluster(&c, &w, &cfg);
            let sim_keys: Vec<Key> = sim.merged.iter().map(|(_, d)| key(d)).collect();
            assert!(!sim_keys.is_empty(), "scenario must produce decisions");
            let dmn_keys = daemon_replay(tag, &sc, order, sim_keys.len());
            assert_eq!(
                dmn_keys, sim_keys,
                "sim/daemon decision keys diverged under {tag} ordering"
            );
        });
    }
}
