//! Golden decision-sequence snapshot: the fig22/fig23 workload mixes
//! run through `simulate` / `simulate_cluster`, with every decision's
//! (board, kind, accel, anchor) tuple compared byte-for-byte against a
//! committed fixture.
//!
//! The point: hot-path work (symbol interning, slab recycling, indexed
//! placement) must be behaviour-preserving, and this test makes any
//! silent scheduling drift a visible diff.  Regenerate the fixture
//! deliberately with:
//!
//! ```text
//! FOS_UPDATE_GOLDEN=1 cargo test --test golden_decisions
//! ```

use fos::accel::Catalog;
use fos::sched::{
    simulate, simulate_cluster, ClusterSimConfig, JobSpec, PlacementKind, Policy, SimConfig,
    SymbolTable, Workload,
};
use fos::shell::ShellBoard;

const FIXTURE: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/golden_decisions.txt");

/// The fig22 time-multiplexing mix: three long Mandelbrot streams next
/// to ten short pinned Sobel frames (fixed smoke-scale sizes so the
/// fixture is identical with and without `FOS_BENCH_SMOKE`).
fn fig22_mix() -> Workload {
    let mut w = Workload::new();
    for _ in 0..3 {
        w.push(JobSpec::stream(0, "mandelbrot", Some("mandelbrot_v1"), 0, 60));
    }
    for j in JobSpec::frame_pinned(1, "sobel", "sobel_v1", 0, 20, 10) {
        w.push(j);
    }
    w
}

/// The fig23 cluster mix: 8 tenants x 4 staggered waves over 8
/// accelerators (the bench's smoke-scale parameters, fixed here).
fn fig23_mix() -> Workload {
    Workload::cluster_mix(8, 4, 3, 8, 400_000)
}

fn boards(n: usize) -> Vec<ShellBoard> {
    (0..n)
        .map(|k| if k % 2 == 0 { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
        .collect()
}

/// Render every decision of every scenario as one line per decision:
/// `<board> <kind> <accel> <anchor>` under a `== scenario ==` header.
fn render(catalog: &Catalog) -> String {
    // Decisions carry interned symbols; resolve through the same
    // deterministic table every core derives from this catalog.
    let symbols = SymbolTable::from_catalog(catalog);
    let mut out = String::new();
    let w22 = fig22_mix();
    for policy in [Policy::Elastic, Policy::Quantum, Policy::ElasticPreempt] {
        let r = simulate(catalog, &w22, &SimConfig::new(ShellBoard::Ultra96, policy));
        out.push_str(&format!("== fig22 {} ==\n", policy.name()));
        for d in &r.decisions {
            out.push_str(&format!("0 {:?} {} {}\n", d.kind, symbols.resolve(d.accel), d.anchor));
        }
    }
    let w23 = fig23_mix();
    for kind in [PlacementKind::RoundRobin, PlacementKind::LeastLoaded, PlacementKind::Locality]
    {
        let r = simulate_cluster(
            catalog,
            &w23,
            &ClusterSimConfig::new(boards(4), Policy::Elastic, kind),
        );
        out.push_str(&format!("== fig23 x4 {} ==\n", kind.name()));
        for (b, d) in &r.merged {
            out.push_str(&format!(
                "{} {:?} {} {}\n",
                b,
                d.kind,
                symbols.resolve(d.accel),
                d.anchor
            ));
        }
    }
    out
}

#[test]
fn decision_sequences_match_committed_fixture() {
    let catalog = Catalog::load_default().unwrap();
    let got = render(&catalog);
    if std::env::var("FOS_UPDATE_GOLDEN").is_ok_and(|v| !v.is_empty() && v != "0") {
        std::fs::create_dir_all(std::path::Path::new(FIXTURE).parent().unwrap()).unwrap();
        std::fs::write(FIXTURE, &got).unwrap();
        eprintln!("golden fixture rewritten: {FIXTURE}");
        return;
    }
    let want = match std::fs::read_to_string(FIXTURE) {
        Ok(w) => w,
        Err(_) => {
            // Bootstrap (the repo's bench-baseline pattern): the first
            // run on a machine with a toolchain arms the fixture from
            // the deterministic sim output; every later run — and any
            // hot-path change — is then gated byte-for-byte against it.
            // Commit the generated file to pin the sequences.
            std::fs::create_dir_all(std::path::Path::new(FIXTURE).parent().unwrap()).unwrap();
            std::fs::write(FIXTURE, &got).unwrap();
            eprintln!("golden fixture bootstrapped: {FIXTURE} — commit it to arm the gate");
            return;
        }
    };
    if got != want {
        // A full-text assert would dump ~10k lines; report the first
        // divergence instead.
        for (i, (g, w)) in got.lines().zip(want.lines()).enumerate() {
            assert_eq!(g, w, "first divergence at fixture line {}", i + 1);
        }
        assert_eq!(
            got.lines().count(),
            want.lines().count(),
            "decision sequence length changed"
        );
        unreachable!("sequences differ but no divergent line found");
    }
}
