//! Cluster sim/daemon scheduling parity: the multi-board discrete-event
//! simulator (`fos::sched::simulate_cluster`) and the multi-fabric
//! daemon (`Daemon::start_cluster`) drive the same
//! `fos::sched::ClusterCore` — routing at admission, one scheduler
//! shard per board, rounds on every board per event batch — so the
//! *same* trace through both must produce the *same* ordered decision
//! sequence **per shard** on a heterogeneous (Ultra96 + ZCU102)
//! 2-board cluster.
//!
//! The daemon side uses `pause` to queue every tenant's jobs before the
//! first dispatch, admitting tenants *sequentially* (routing is
//! admission-order dependent), then `resume`s and compares its
//! per-board decision logs against the simulator's.

use fos::accel::Catalog;
use fos::daemon::{Daemon, FpgaRpc, Job};
use fos::sched::{
    simulate_cluster, AdmissionConfig, ClusterSimConfig, ClusterSimResult, Decision,
    DecisionKind, FaultPlan, JobSpec, PlacementKind, Policy, Sym, Workload,
};
use fos::shell::ShellBoard;
use std::path::PathBuf;

/// (kind, accel, variant, anchor, span, reconfigure, replicated, tiles)
///
/// Accel/variant are interned symbols; both harnesses derive the same
/// deterministic table from the shared catalog, so equal syms mean
/// equal names.
type Key = (DecisionKind, Sym, Sym, usize, usize, bool, bool, usize);

fn key(d: &Decision) -> Key {
    (d.kind, d.accel, d.variant, d.anchor, d.span, d.reconfigure, d.replicated, d.tiles)
}

fn sock(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fos_cluster_{name}_{}.sock", std::process::id()))
}

const BOARDS: [ShellBoard; 2] = [ShellBoard::Ultra96, ShellBoard::Zcu102];

/// One tenant's slice of a trace: (accel, requests, tiles_per_request).
type Trace = [(&'static str, usize, usize)];

fn sim_side(catalog: &Catalog, trace: &Trace, policy: Policy) -> ClusterSimResult {
    sim_side_with_faults(catalog, trace, policy, None)
}

fn sim_side_with_faults(
    catalog: &Catalog,
    trace: &Trace,
    policy: Policy,
    faults: Option<FaultPlan>,
) -> ClusterSimResult {
    // All arrivals at t=0, jobs in tenant order — matching the
    // daemon side's sequential admission exactly.
    let mut w = Workload::new();
    for (u, &(accel, requests, tiles)) in trace.iter().enumerate() {
        w.push(JobSpec {
            user: u,
            accel: accel.to_string(),
            arrival: 0,
            requests,
            tiles_per_request: tiles,
            pin_variant: None,
        });
    }
    let mut cfg = ClusterSimConfig::new(BOARDS.to_vec(), policy, PlacementKind::Locality);
    cfg.faults = faults;
    simulate_cluster(catalog, &w, &cfg)
}

/// Start a paused 2-board cluster daemon, admit each tenant's jobs in
/// strict tenant order (board routing happens at admission, so the
/// order must match the simulator's), resume, and wait for the drain.
fn daemon_side(name: &str, catalog: &Catalog, trace: &'static Trace, policy: Policy) -> Daemon {
    daemon_side_with_faults(name, catalog, trace, policy, None)
}

fn daemon_side_with_faults(
    name: &str,
    catalog: &Catalog,
    trace: &'static Trace,
    policy: Policy,
    faults: Option<FaultPlan>,
) -> Daemon {
    let path = sock(name);
    let daemon = Daemon::start_cluster_with_faults(
        &path,
        &BOARDS,
        catalog.clone(),
        policy,
        PlacementKind::Locality,
        AdmissionConfig::default(),
        fos::daemon::DEFAULT_MAX_CONNECTIONS,
        faults,
    )
    .unwrap();
    let mut control = FpgaRpc::connect(&path).unwrap();
    control.pause().unwrap();

    let mut handles = Vec::new();
    let mut admitted = 0u64;
    for &(accel, requests, tiles) in trace.iter() {
        let mut rpc = FpgaRpc::connect(&path).unwrap();
        let catalog = catalog.clone();
        handles.push(std::thread::spawn(move || {
            let params = fos::testutil::alloc_operand_params(&mut rpc, &catalog, accel);
            let jobs: Vec<Job> = (0..requests)
                .map(|_| Job::new(accel, params.clone()).with_tiles(tiles))
                .collect();
            // Decisions are logged even when the PJRT backend is a stub
            // and execution errors — tolerate either outcome.
            let _ = rpc.run(&jobs);
        }));
        // Routing is admission-order dependent: wait until this
        // tenant's jobs are all queued before admitting the next.
        admitted += requests as u64;
        for _ in 0..2000 {
            if control.sched_stats().unwrap().queued == admitted {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(control.sched_stats().unwrap().queued, admitted, "jobs not admitted");
    }
    control.resume().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    daemon
}

#[test]
fn cluster_sim_and_daemon_agree_per_shard() {
    // Two tenants, two accelerators, enough backlog that locality
    // routing spreads requests over both heterogeneous boards.
    static TRACE: &Trace = &[("mandelbrot", 4, 4), ("sobel", 3, 2)];
    let catalog = Catalog::load_default().unwrap();

    let sim = sim_side(&catalog, TRACE, Policy::Elastic);
    let total: usize = sim.boards.iter().map(|b| b.decisions.len()).sum();
    assert_eq!(total, 7, "sanity: every request decided once");
    assert!(
        sim.boards.iter().all(|b| !b.decisions.is_empty()),
        "trace must exercise both boards: {:?}",
        sim.boards.iter().map(|b| b.decisions.len()).collect::<Vec<_>>()
    );

    let daemon = daemon_side("elastic", &catalog, TRACE, Policy::Elastic);

    // Per-shard decision sequences match verbatim.
    for b in 0..BOARDS.len() {
        let sim_seq: Vec<Key> = sim.boards[b].decisions.iter().map(key).collect();
        let dmn_seq: Vec<Key> = daemon.board_decision_log(b).iter().map(key).collect();
        assert_eq!(sim_seq, dmn_seq, "board {b} decision sequences diverged");
    }
    // The merged log is the same set, in the same global order.
    let merged_sim: Vec<Key> = sim.merged.iter().map(|(_, d)| key(d)).collect();
    let merged_dmn: Vec<Key> = daemon.decision_log().iter().map(key).collect();
    assert_eq!(merged_sim, merged_dmn, "merged decision order diverged");

    // Per-board counters agree (same per-shard SchedCounters source).
    use std::sync::atomic::Ordering::Relaxed;
    for (b, board) in sim.boards.iter().enumerate() {
        let pb = &daemon.stats().per_board[b];
        assert_eq!(board.counters.reconfigs, pb.reconfigs.load(Relaxed), "board {b}");
        assert_eq!(board.counters.reuses, pb.reuses.load(Relaxed), "board {b}");
        assert_eq!(board.counters.skips, pb.skips.load(Relaxed), "board {b}");
        assert_eq!(board.counters.replications, pb.replications.load(Relaxed), "board {b}");
    }
    // Routing counters agree too.
    assert_eq!(daemon.stats().routed.load(Relaxed), sim.cluster.routed);
    assert_eq!(daemon.stats().steals.load(Relaxed), sim.cluster.steals);
}

#[test]
fn cluster_parity_holds_under_preemption() {
    // Six long mandelbrot streams and twelve short sobel jobs: the
    // least-loaded fallback splits them 3 + 6 per board, so the
    // Ultra96 shard reproduces `tests/sched_parity.rs`'s proven
    // preemption scenario (3 streams filling the fabric, shorts
    // starved past the quantum) — and the per-board Preempt/Resume
    // sequences must still match between simulator and daemon.
    static TRACE: &Trace = &[("mandelbrot", 6, 40), ("sobel", 12, 2)];
    let catalog = Catalog::load_default().unwrap();

    let sim = sim_side(&catalog, TRACE, Policy::Quantum);
    let preemptions: u64 = sim.boards.iter().map(|b| b.counters.preemptions).sum();
    assert!(preemptions >= 1, "trace must actually preempt: {:?}", sim.boards[0].counters);

    let daemon = daemon_side("preempt", &catalog, TRACE, Policy::Quantum);
    for b in 0..BOARDS.len() {
        let sim_seq: Vec<Key> = sim.boards[b].decisions.iter().map(key).collect();
        let dmn_seq: Vec<Key> = daemon.board_decision_log(b).iter().map(key).collect();
        assert_eq!(sim_seq, dmn_seq, "board {b} preemptive sequences diverged");
    }
    use std::sync::atomic::Ordering::Relaxed;
    for (b, board) in sim.boards.iter().enumerate() {
        let pb = &daemon.stats().per_board[b];
        assert_eq!(board.counters.preemptions, pb.preemptions.load(Relaxed), "board {b}");
        assert_eq!(board.counters.resumes, pb.resumes.load(Relaxed), "board {b}");
    }
}

#[test]
fn fault_parity_same_plan_drives_identical_failover_sequences() {
    // The failure-domain parity claim: the SAME FaultPlan — one board
    // killed mid-run — driven through simulate_cluster and a live
    // 2-board daemon yields identical per-shard and merged decision
    // sequences, the board-down drain's Preempt (migration) decisions
    // and the migrated remainders' Resume decisions included.
    static TRACE: &Trace = &[("mandelbrot", 4, 30), ("sobel", 6, 2)];
    let catalog = Catalog::load_default().unwrap();

    // Probe the fault-free virtual makespan so the outage lands while
    // work is genuinely running on the victim board.
    let clean = sim_side(&catalog, TRACE, Policy::Elastic);
    let outage_at = clean.makespan / 2;
    let plan = FaultPlan::new(5).with_outage(1, outage_at, clean.makespan * 4);

    let sim = sim_side_with_faults(&catalog, TRACE, Policy::Elastic, Some(plan.clone()));
    assert_eq!(sim.failovers(), 1, "the plan must actually kill board 1");
    assert!(sim.migrations() >= 1, "the outage must migrate work: {:?}", sim.cluster);
    assert!(
        sim.merged
            .iter()
            .any(|(b, d)| *b == 1 && d.kind == DecisionKind::Preempt),
        "the drain must appear in the decision sequence"
    );
    assert!(sim.job_completion.iter().all(|&t| t > 0), "migration loses nothing");

    let daemon =
        daemon_side_with_faults("faults", &catalog, TRACE, Policy::Elastic, Some(plan));

    // Identical per-shard decision sequences — migration decisions
    // included — and the identical merged global order.
    for b in 0..BOARDS.len() {
        let sim_seq: Vec<Key> = sim.boards[b].decisions.iter().map(key).collect();
        let dmn_seq: Vec<Key> = daemon.board_decision_log(b).iter().map(key).collect();
        assert_eq!(sim_seq, dmn_seq, "board {b} failover sequences diverged");
    }
    let merged_sim: Vec<(usize, DecisionKind)> =
        sim.merged.iter().map(|(b, d)| (*b, d.kind)).collect();
    let merged_dmn: Vec<(usize, DecisionKind)> = daemon
        .merged_decision_log()
        .iter()
        .map(|(b, d)| (*b, d.kind))
        .collect();
    assert_eq!(merged_sim, merged_dmn, "merged (board, kind) order diverged");

    // Failover accounting agrees.
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(daemon.stats().failovers.load(Relaxed), sim.cluster.failovers);
    assert_eq!(daemon.stats().migrations.load(Relaxed), sim.cluster.migrations);
    assert_eq!(daemon.stats().lost_ns.load(Relaxed), sim.cluster.lost_ns);
    for (b, board) in sim.boards.iter().enumerate() {
        let pb = &daemon.stats().per_board[b];
        assert_eq!(board.counters.preemptions, pb.preemptions.load(Relaxed), "board {b}");
        assert_eq!(board.counters.resumes, pb.resumes.load(Relaxed), "board {b}");
    }
}
