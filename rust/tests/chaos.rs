//! Chaos suite: seeded fault plans against the cluster scheduler.
//!
//! Every property sweeps `FOS_CHAOS_SEEDS` deterministic seeds
//! (default 6 — the tier-1 failover-conservation gate; nightly runs
//! ≥ 64).  Each seed derives a workload and a [`FaultPlan`] (board
//! outage + reconfiguration/transient-run failure rates) and asserts
//! the failure-domain invariants:
//!
//! - **Conservation** — no request is lost or double-completed across
//!   checkpoint-based migration: per tenant,
//!   `admitted == completed + rejected`, and every job terminates.
//! - **Tenant consistency** — the per-tenant counters aggregated
//!   across shards account for every admitted request exactly once,
//!   migrations included.
//! - **Revival** — a board that went down and revived is eventually
//!   routed to again.
//!
//! On failure a repro artifact (seed + fault-plan spec) is written to
//! `FOS_CHAOS_REPRO_DIR` — the nightly workflow uploads that directory
//! when red, so any failing `(plan, seed)` pair replays locally with
//! `FOS_CHAOS_SEEDS` and the printed spec.
//!
//! The file also carries the driver-level failover integration test:
//! checkpoint on one board → board down → restore on another board's
//! `Cynq` stack, progress preserved.

use fos::accel::Catalog;
use fos::sched::{
    simulate_cluster, ClusterSimConfig, FaultPlan, JobSpec, PlacementKind, Policy, Workload,
};
use fos::shell::ShellBoard;
use fos::testutil::Rng;

fn catalog() -> Catalog {
    Catalog::load_default().unwrap()
}

fn boards(n: usize) -> Vec<ShellBoard> {
    (0..n)
        .map(|i| if i % 2 == 0 { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
        .collect()
}

/// Seeds swept per property: `FOS_CHAOS_SEEDS` (nightly ≥ 64),
/// defaulting to a small fixed set for the tier-1 gate.
fn chaos_seeds() -> u64 {
    std::env::var("FOS_CHAOS_SEEDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(6)
}

/// Write a failure repro (seed + plan spec) for the nightly artifact
/// upload; no-op unless `FOS_CHAOS_REPRO_DIR` is set.
fn write_repro(name: &str, seed: u64, plan: &FaultPlan, detail: &str) {
    let Ok(dir) = std::env::var("FOS_CHAOS_REPRO_DIR") else { return };
    let _ = std::fs::create_dir_all(&dir);
    let path = std::path::Path::new(&dir).join(format!("{name}_seed_{seed}.txt"));
    let _ = std::fs::write(
        &path,
        format!(
            "test: {name}\nseed: {seed}\nfault_plan: {}\ndetail: {detail}\n\
             rerun: FOS_CHAOS_SEEDS={} cargo test --test chaos {name}\n",
            plan.to_spec(),
            seed + 1,
        ),
    );
}

/// Run one seeded case under `catch_unwind`; on failure, persist the
/// repro and re-raise with the seed + plan spec in the message.
fn seeded_case(name: &str, seed: u64, plan: &FaultPlan, case: impl FnOnce()) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(case));
    if let Err(e) = result {
        write_repro(name, seed, plan, "assertion failed (see test log)");
        eprintln!("chaos {name} failed at seed {seed}; fault plan: {}", plan.to_spec());
        eprintln!("repro: FOS_CHAOS_SEEDS={} cargo test --test chaos {name}", seed + 1);
        std::panic::resume_unwind(e);
    }
}

/// Seed-derived adversarial mix: 2–4 heterogeneous boards, 2–6
/// tenants, staggered multi-wave traffic.
fn chaos_workload(seed: u64) -> (usize, Workload) {
    let mut rng = Rng::new(seed ^ 0x00C4A05);
    let n_boards = rng.range(2, 5);
    let tenants = rng.range(2, 7);
    let waves = rng.range(1, 4);
    let reqs = rng.range(1, 4);
    let tiles = rng.range(2, 10);
    (n_boards, Workload::cluster_mix(tenants, waves, reqs, tiles, 200_000))
}

#[test]
fn prop_chaos_conserves_requests_and_tenant_counters() {
    let c = catalog();
    for seed in 0..chaos_seeds() {
        let (n_boards, w) = chaos_workload(seed);
        // Probe the fault-free makespan so the outage lands mid-run.
        let clean = simulate_cluster(
            &c,
            &w,
            &ClusterSimConfig::new(boards(n_boards), Policy::Elastic, PlacementKind::Locality),
        );
        let plan = FaultPlan::chaos(seed, n_boards, clean.makespan.max(1));
        seeded_case("conservation", seed, &plan, || {
            let cfg = ClusterSimConfig::new(
                boards(n_boards),
                Policy::Elastic,
                PlacementKind::Locality,
            )
            .with_faults(plan.clone());
            let r = simulate_cluster(&c, &w, &cfg);
            // Every request was admitted exactly once…
            let admitted: u64 = r.per_tenant.iter().map(|(_, tc)| tc.admitted).sum();
            assert_eq!(admitted, w.total_requests() as u64, "admission must be exact");
            // …and ended exactly one way — completed, or structurally
            // rejected at the reconfiguration retry cap.  Nothing lost
            // to the outage, nothing double-completed by migration —
            // per tenant, not just in aggregate.
            for (t, tc) in &r.per_tenant {
                assert_eq!(
                    tc.completed + tc.rejected,
                    tc.admitted,
                    "tenant {t} counters leak under {:?}",
                    r.cluster
                );
            }
            // Every job terminates (a rejection still terminates it).
            assert!(
                r.job_completion.iter().all(|&t| t > 0),
                "job lost: {:?}",
                r.job_completion
            );
            // The injected outage really drove a failover.
            assert_eq!(r.failovers(), 1, "{:?}", r.cluster);
        });
    }
}

#[test]
fn prop_chaos_outage_only_loses_zero_requests() {
    // The acceptance scenario isolated: outage with NO failure rates —
    // 100% of admitted requests must complete (zero rejections, zero
    // lost work) via checkpoint-based migration alone.
    let c = catalog();
    for seed in 0..chaos_seeds() {
        let (n_boards, w) = chaos_workload(seed);
        let clean = simulate_cluster(
            &c,
            &w,
            &ClusterSimConfig::new(boards(n_boards), Policy::Elastic, PlacementKind::Locality),
        );
        let h = clean.makespan.max(8);
        let board = (seed as usize) % n_boards;
        let plan = FaultPlan::new(seed).with_outage(board, h / 3, h / 3);
        seeded_case("outage_only", seed, &plan, || {
            let cfg = ClusterSimConfig::new(
                boards(n_boards),
                Policy::Elastic,
                PlacementKind::Locality,
            )
            .with_faults(plan.clone());
            let r = simulate_cluster(&c, &w, &cfg);
            let completed: u64 = r.per_tenant.iter().map(|(_, tc)| tc.completed).sum();
            let rejected: u64 = r.per_tenant.iter().map(|(_, tc)| tc.rejected).sum();
            assert_eq!(rejected, 0, "an outage alone must never reject");
            assert_eq!(completed, w.total_requests() as u64, "zero lost work");
            assert!(r.job_completion.iter().all(|&t| t > 0));
        });
    }
}

#[test]
fn chaos_revived_board_is_eventually_reused() {
    let c = catalog();
    // Wave A keeps the cluster busy through the outage; wave B arrives
    // long after the revival, so a correctly revived board 1 must
    // serve part of it (round-robin guarantees a visit).
    let mut w = Workload::new();
    for t in 0..3 {
        w.push(JobSpec::stream(t, "mandelbrot", Some("mandelbrot_v1"), 0, 40));
    }
    let base = ClusterSimConfig::new(boards(3), Policy::Elastic, PlacementKind::RoundRobin);
    let clean = simulate_cluster(&c, &w, &base);
    let (down_at, dur) = (clean.makespan / 4, clean.makespan / 4);
    let wave_b_start = w.jobs.len() as u64;
    for t in 0..3 {
        w.push(JobSpec {
            user: t,
            accel: "sobel".to_string(),
            arrival: down_at + dur + clean.makespan,
            requests: 2,
            tiles_per_request: 2,
            pin_variant: Some("sobel_v1".to_string()),
        });
    }
    let plan = FaultPlan::new(1).with_outage(1, down_at, dur);
    seeded_case("revive_reuse", 1, &plan, || {
        let cfg = ClusterSimConfig::new(boards(3), Policy::Elastic, PlacementKind::RoundRobin)
            .with_faults(plan.clone());
        let r = simulate_cluster(&c, &w, &cfg);
        assert_eq!(r.failovers(), 1);
        assert!(r.job_completion.iter().all(|&t| t > 0), "every job completes");
        // No decision may land on board 1 while it is down…
        // (wave B is the only work after the revival, so any board-1
        // decision with a wave-B job proves the revival took.)
        let reused = r
            .merged
            .iter()
            .any(|(b, d)| *b == 1 && d.job >= wave_b_start);
        assert!(
            reused,
            "revived board 1 never reused: {:?}",
            r.merged.iter().map(|(b, d)| (*b, d.job)).collect::<Vec<_>>()
        );
    });
}

#[test]
fn checkpoint_board_down_restore_on_other_board() {
    // Driver-level failover: a register-file snapshot captured on one
    // board's Cynq stack restores onto a DIFFERENT board's fresh load
    // of the same accelerator/variant, progress counter included —
    // the hardware half of cross-board checkpoint migration.
    use fos::driver::Cynq;
    let catalog = catalog();
    let mut a = Cynq::open(ShellBoard::Ultra96, catalog.clone()).unwrap();
    let mut b = Cynq::open(ShellBoard::Zcu102, catalog.clone()).unwrap();

    let (ha, _) = a.load_accelerator("vadd", Some("vadd_v1")).unwrap();
    let pa = a.alloc(4 * 4096).unwrap();
    let pb = a.alloc(4 * 4096).unwrap();
    let pc = a.alloc(4 * 4096).unwrap();
    a.write_reg(ha, "a_op", pa).unwrap();
    a.write_reg(ha, "b_op", pb).unwrap();
    a.write_reg(ha, "c_out", pc).unwrap();
    let compute = fos::testutil::pjrt_available();
    if compute {
        a.write_f32(pa, &vec![1.0; 4096]).unwrap();
        a.write_f32(pb, &vec![2.0; 4096]).unwrap();
        a.run(ha).unwrap();
        a.run(ha).unwrap();
    }
    let snap = a.checkpoint_accelerator(ha).unwrap();
    let done = snap.tiles_done;
    assert_eq!(done, if compute { 2 } else { 0 });

    // "Board A fails": its module is gone, but the snapshot lives in
    // the daemon's store and restores onto board B.
    a.unload(ha).unwrap();
    let (hb, _) = b.load_accelerator("vadd", Some("vadd_v1")).unwrap();
    b.restore_accelerator(hb, &snap).unwrap();
    assert_eq!(b.progress_of(hb), Some(done), "progress migrates with the snapshot");

    if compute {
        // Lockstep allocators: the same alloc sequence on board B
        // yields the same physical addresses, so the restored register
        // file points at valid (mirrored) operands and the batch
        // CONTINUES — it does not restart.
        let qa = b.alloc(4 * 4096).unwrap();
        let qb = b.alloc(4 * 4096).unwrap();
        let qc = b.alloc(4 * 4096).unwrap();
        assert_eq!((qa.0, qb.0, qc.0), (pa.0, pb.0, pc.0), "arenas must agree");
        b.write_f32(qa, &vec![1.0; 4096]).unwrap();
        b.write_f32(qb, &vec![2.0; 4096]).unwrap();
        b.run(hb).unwrap();
        assert_eq!(b.progress_of(hb), Some(done + 1), "continues, not restarts");
        let out = b.read_f32(qc, 4096).unwrap();
        assert!(out.iter().all(|&v| v == 3.0));
    }

    // A mismatched target still rolls back (variant-checked restore).
    let (hc, _) = b.load_accelerator("dct", None).unwrap();
    assert!(b.restore_accelerator(hc, &snap).is_err());
    assert_eq!(b.progress_of(hc), Some(0), "failed restore leaves the slot untouched");
}

#[test]
fn fault_parity_same_plan_same_seed_same_outcome() {
    // The determinism contract underneath everything: the same plan
    // (same seed) through two separate simulator runs produces
    // bit-identical merged decision sequences AND identical failover
    // accounting — this is what makes a nightly repro artifact
    // actually reproduce.
    let c = catalog();
    let (n_boards, w) = chaos_workload(3);
    let clean = simulate_cluster(
        &c,
        &w,
        &ClusterSimConfig::new(boards(n_boards), Policy::Elastic, PlacementKind::Locality),
    );
    let plan = FaultPlan::chaos(3, n_boards, clean.makespan.max(1));
    let run = || {
        simulate_cluster(
            &c,
            &w,
            &ClusterSimConfig::new(boards(n_boards), Policy::Elastic, PlacementKind::Locality)
                .with_faults(plan.clone()),
        )
    };
    let (r1, r2) = (run(), run());
    assert_eq!(r1.merged, r2.merged, "fault injection must be deterministic");
    assert_eq!(r1.cluster, r2.cluster);
    assert_eq!(r1.job_completion, r2.job_completion);
    // And the spec round-trips: a repro artifact's parsed plan replays
    // the identical run.
    let reparsed = FaultPlan::parse(&plan.to_spec()).unwrap();
    let r3 = simulate_cluster(
        &c,
        &w,
        &ClusterSimConfig::new(boards(n_boards), Policy::Elastic, PlacementKind::Locality)
            .with_faults(reparsed),
    );
    assert_eq!(r1.merged, r3.merged, "spec round-trip must replay identically");
}

/// The live half of the security-domain chaos gate: a fixed-seed
/// fault plan (board-1 outage landing mid-batch) against a real
/// two-board daemon in authenticated mode, with two token-bound
/// tenants computing concurrently.  Invariants:
///
/// - a bind with a wrong token is denied (structured, connection
///   survives);
/// - per-tenant conservation holds on the live counters — every
///   admitted request completes exactly once across the
///   checkpoint-based migration (outage-only plans never reject);
/// - zero cross-arena leaks: each tenant's inputs re-read intact
///   and its outputs are its own arithmetic, while a stolen handle
///   from the neighbour is denied even after failover moved work.
///
/// `reactor_shards` picks the network-plane topology: 1 is the
/// single-threaded reactor, >1 the acceptor + per-shard reactors —
/// the dispatcher (and thus every invariant above) must not care.
fn chaos_live_two_tenants(reactor_shards: usize, sock_tag: &str) {
    use fos::daemon::{Daemon, DaemonConfig, FpgaRpc, Job};
    if !fos::testutil::pjrt_available() {
        eprintln!("skipping: PJRT backend unavailable (offline stub)");
        return;
    }
    let path = std::env::temp_dir()
        .join(format!("fos_chaos_live_{sock_tag}_{}.sock", std::process::id()));
    let plan = FaultPlan::new(11).with_outage(1, 1_000, 2_000_000);
    let cfg = DaemonConfig::new(&boards(2), catalog())
        .placement(PlacementKind::RoundRobin)
        .faults(plan)
        .tenants(&["acme", "bigco"])
        .reactor_shards(reactor_shards);
    let d = Daemon::start_configured(&path, cfg).unwrap();

    // Wrong token: denied, structured, and the connection survives.
    let mut probe = FpgaRpc::connect(&path).unwrap();
    assert!(probe.set_session("acme", Some("stolen"), 1, 0).is_err());
    probe.ping().unwrap();

    let worker = |tenant: &'static str, token: String, base: f32, n_jobs: usize| {
        let path = path.clone();
        std::thread::spawn(move || {
            let mut rpc = FpgaRpc::connect(&path).unwrap();
            let id = rpc.set_session(tenant, Some(&token), 1, 0).unwrap();
            let n = 4096;
            let a = rpc.alloc(4 * n).unwrap();
            let b = rpc.alloc(4 * n).unwrap();
            let c = rpc.alloc(4 * n).unwrap();
            rpc.write_f32(a, &vec![base; n]).unwrap();
            rpc.write_f32(b, &vec![2.0 * base; n]).unwrap();
            let jobs: Vec<Job> = (0..n_jobs)
                .map(|_| {
                    Job::new(
                        "vadd",
                        vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
                    )
                })
                .collect();
            let report = rpc.run(&jobs).unwrap();
            assert_eq!(report.latencies_us.len(), n_jobs);
            // The tenant's own arena after failover/migration: inputs
            // bit-for-bit intact, output its own sum — not the
            // neighbour's (who computes with a different base).
            assert_eq!(rpc.read_f32(a, n).unwrap(), vec![base; n]);
            let out = rpc.read_f32(c, n).unwrap();
            assert!(out.iter().all(|&v| (v - 3.0 * base).abs() < 1e-4), "arena leaked");
            (rpc, id, c)
        })
    };
    let acme = worker("acme", d.tenant_token("acme").unwrap(), 1.0, 8);
    let bigco = worker("bigco", d.tenant_token("bigco").unwrap(), 10.0, 8);
    let (mut acme_rpc, acme_id, _) = acme.join().unwrap();
    let (_bigco_rpc, bigco_id, bigco_out) = bigco.join().unwrap();
    assert_ne!(acme_id, bigco_id);

    // Cross-arena theft with a live handle, after migration: denied.
    assert!(acme_rpc.read_f32(bigco_out, 16).is_err());

    // Per-tenant conservation on the live counters: both batches
    // returned, so every admitted request completed exactly once —
    // the outage migrated work, it did not lose or duplicate it.
    let stats = acme_rpc.sched_stats().unwrap();
    for t in stats
        .tenants
        .iter()
        .filter(|t| t.tenant == acme_id || t.tenant == bigco_id)
    {
        assert_eq!(t.enqueued, 8, "tenant {}: {t:?}", t.tenant);
        assert_eq!(t.admitted, 8, "tenant {}: {t:?}", t.tenant);
        assert_eq!(t.completed, 8, "tenant {}: {t:?}", t.tenant);
        assert_eq!(t.sched_rejected, 0, "outage-only plans never reject: {t:?}");
    }
    assert_eq!(
        stats.tenants.iter().filter(|t| t.tenant == acme_id || t.tenant == bigco_id).count(),
        2,
        "both tenants accounted: {:?}",
        stats.tenants
    );
}

#[test]
fn chaos_live_daemon_isolates_two_authenticated_tenants_across_failover() {
    chaos_live_two_tenants(1, "1shard");
}

#[test]
fn chaos_live_sharded_reactor_replays_fault_plan_identically() {
    // Same fault plan, same tenants, same invariants — but the network
    // plane runs 3 reactor shards behind the acceptor.  Sharding only
    // moves connection I/O; fault replay, failover migration and
    // per-tenant conservation are dispatcher state and must hold
    // unchanged (CI's chaos gate runs this alongside the 1-shard
    // variant).
    chaos_live_two_tenants(3, "3shard");
}
