//! Table 5: re-initialisation latencies for component updates on both
//! boards — partial/full reconfiguration modelled through the PCAP
//! model, runtime restart measured for real.

use fos::accel::Catalog;
use fos::bitstream::{extract, synth_full};
use fos::daemon::Daemon;
use fos::fabric::{Device, DeviceKind, Floorplan};
use fos::metrics::Table;
use fos::reconfig::{FpgaManager, KERNEL_REBOOT_U96, KERNEL_REBOOT_ZCU102};
use fos::shell::ShellBoard;
use std::time::Instant;

fn accel_and_shell_ms(kind: DeviceKind) -> (f64, f64) {
    let fp = Floorplan::standard(Device::new(kind));
    let full = synth_full(&fp.device, 3);
    let partial = extract(&fp.device, &full, &fp.regions[0]).unwrap();
    let accel = FpgaManager::latency_for(partial.config_bytes(), true);
    let shell = FpgaManager::latency_for(full.config_bytes(), false);
    (accel.as_secs_f64() * 1e3, shell.as_secs_f64() * 1e3)
}

fn main() {
    let (u96_a, u96_s) = accel_and_shell_ms(DeviceKind::Zu3eg);
    let (zcu_a, zcu_s) = accel_and_shell_ms(DeviceKind::Zu9eg);

    // Runtime restart: really restart the daemon and measure.
    let socket = std::env::temp_dir().join(format!("fos_t5_{}.sock", std::process::id()));
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let mut daemon = Daemon::start(&socket, ShellBoard::Ultra96, catalog.clone()).unwrap();
    let t0 = Instant::now();
    daemon.shutdown();
    drop(daemon);
    let _daemon = Daemon::start(&socket, ShellBoard::Ultra96, catalog).unwrap();
    let runtime_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut t = Table::new(
        "Table 5 — component re-initialisation latency, measured (paper), ms",
        &["component updated", "Ultra96", "ZCU102"],
    );
    t.row(&[
        "Accelerator".into(),
        format!("{u96_a:.2} (3.81)"),
        format!("{zcu_a:.2} (6.77)"),
    ]);
    t.row(&[
        "Shell".into(),
        format!("{u96_s:.2} (20.74)"),
        format!("{zcu_s:.2} (98.4)"),
    ]);
    t.row(&[
        "Runtime".into(),
        format!("{runtime_ms:.1} (15.2)"),
        format!("{runtime_ms:.1} (15.2)"),
    ]);
    t.row(&[
        "Kernel (reboot)".into(),
        format!("{:.0} (66000)", KERNEL_REBOOT_U96.as_secs_f64() * 1e3),
        format!("{:.0} (15760)", KERNEL_REBOOT_ZCU102.as_secs_f64() * 1e3),
    ]);
    t.print();
    println!("runtime restart is a REAL daemon stop+start (incl. shell reload + PJRT bring-up).");
}
