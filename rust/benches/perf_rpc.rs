//! §Perf microbench: daemon RPC path — ping RTT, bulk write throughput
//! (base64-over-socket vs shared memory), and request dispatch rate.
//! Target: RTT ≤ 1 ms (paper: 0.71 ms gRPC call).

use fos::accel::Catalog;
use fos::daemon::{Daemon, FpgaRpc, Job, SharedMem};
use fos::metrics::LatencyStats;
use fos::shell::ShellBoard;
use std::time::Instant;

fn main() {
    let socket = std::env::temp_dir().join(format!("fos_perf_rpc_{}.sock", std::process::id()));
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let _daemon = Daemon::start(&socket, ShellBoard::Ultra96, catalog).unwrap();
    let mut rpc = FpgaRpc::connect(&socket).unwrap();

    // Ping RTT.
    let mut pings = LatencyStats::new();
    for _ in 0..fos::testutil::bench_scale(500, 50) {
        pings.record(rpc.ping().unwrap());
    }
    println!("{}", pings.summary("ping RTT"));
    assert!(pings.mean_us() < 1000.0, "RTT above 1 ms target");

    // Bulk data: socket (base64) vs shared memory.
    let n = 65536; // 256 KiB
    let data: Vec<f32> = (0..n).map(|i| i as f32).collect();
    let addr = rpc.alloc(4 * n).unwrap();
    let t0 = Instant::now();
    let iters = fos::testutil::bench_scale(20, 3);
    for _ in 0..iters {
        rpc.write_f32(addr, &data).unwrap();
    }
    let sock_mbps = (4 * n * iters) as f64 / t0.elapsed().as_secs_f64() / 1e6;
    println!("socket write (base64): {sock_mbps:.0} MB/s");

    let shm_path = std::env::temp_dir().join(format!("fos_perf_shm_{}.bin", std::process::id()));
    let mut shm = SharedMem::create(&shm_path, 4 * n).unwrap();
    shm.write_f32(0, &data).unwrap();
    let t0 = Instant::now();
    for _ in 0..iters {
        rpc.import_shm(&shm.path, 0, n, addr).unwrap();
    }
    let shm_mbps = (4 * n * iters) as f64 / t0.elapsed().as_secs_f64() / 1e6;
    println!("shm import (zero-copy socket): {shm_mbps:.0} MB/s ({:.1}x faster)", shm_mbps / sock_mbps);

    // Dispatch rate with real compute (vadd). Skipped gracefully when
    // the PJRT backend is the offline stub — the RTT/bandwidth numbers
    // above are the bench's primary guard either way.
    let a = rpc.alloc(4 * 4096).unwrap();
    let b = rpc.alloc(4 * 4096).unwrap();
    let c = rpc.alloc(4 * 4096).unwrap();
    rpc.write_f32(a, &vec![1.0; 4096]).unwrap();
    rpc.write_f32(b, &vec![2.0; 4096]).unwrap();
    let n_jobs = fos::testutil::bench_scale(100, 10);
    let jobs: Vec<Job> = (0..n_jobs)
        .map(|_| Job::new(
            "vadd",
            vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
        ))
        .collect();
    let t0 = Instant::now();
    match rpc.run(&jobs) {
        Ok(report) => {
            let el = t0.elapsed();
            println!(
                "{n_jobs} vadd requests (real PJRT compute): {el:?} -> {:.0} req/s, daemon-side mean {:.0} us",
                n_jobs as f64 / el.as_secs_f64(),
                report.latencies_us.iter().sum::<f64>() / report.latencies_us.len().max(1) as f64
            );
        }
        Err(e) => println!("dispatch-rate leg skipped (PJRT backend unavailable: {e})"),
    }
}
