//! Fig 17: memory throughput vs burst size on the Ultra96's duplex AXI
//! HP ports (HP0, HP1, HP3), individually and all together.

use fos::memsim::{config_for, DdrModel, PortLoad};
use fos::metrics::Table;
use fos::shell::ShellBoard;

fn main() {
    let m = DdrModel::new(config_for(ShellBoard::Ultra96));
    let mut t = Table::new(
        "Fig 17 — Ultra96 AXI throughput vs burst size (MB/s)",
        &["burst (B)", "read/port", "write/port", "1 port total", "3 ports total"],
    );
    for burst in [16u32, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
        let one = m.steady_state(&[PortLoad::duplex(burst)]);
        let all = m.steady_state(&[PortLoad::duplex(burst); 3]);
        t.row(&[
            burst.to_string(),
            format!("{:.0}", one.per_port_dir_mbps[0].0),
            format!("{:.0}", one.per_port_dir_mbps[0].1),
            format!("{:.0}", one.total_mbps),
            format!("{:.0}", all.total_mbps),
        ]);
    }
    t.print();
    let one = m.steady_state(&[PortLoad::duplex(1024)]);
    let all = m.steady_state(&[PortLoad::duplex(1024); 3]);
    println!("paper: ~530 MB/s per direction, ~1060 MB/s per port, 3187 MB/s all ports");
    println!(
        "measured @1KiB: {:.0} per direction, {:.0} per port, {:.0} all ports ({:.0}% of the 4280 MB/s LPDDR4 peak; paper: 74%)",
        one.per_port_dir_mbps[0].0,
        one.total_mbps,
        all.total_mbps,
        100.0 * all.total_mbps / 4280.0
    );
}
