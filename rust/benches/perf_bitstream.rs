//! §Perf microbench: BitMan-analog throughput — extraction, relocation,
//! merge and (de)serialisation rates. Target: relocation ≥ 1 GB/s of
//! configuration data (it's on the scheduler's reconfiguration path).

use fos::bitstream::{extract, merge, relocate, synth_full, Bitstream};
use fos::fabric::{Device, DeviceKind, Floorplan};
use std::time::Instant;

fn rate(bytes: usize, iters: usize, el: std::time::Duration) -> f64 {
    (bytes * iters) as f64 / el.as_secs_f64() / 1e9
}

fn main() {
    let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
    let full = synth_full(&fp.device, 42);
    let iters = fos::testutil::bench_scale(50, 5);

    let t0 = Instant::now();
    let mut partial = None;
    for _ in 0..iters {
        partial = Some(extract(&fp.device, &full, &fp.regions[0]).unwrap());
    }
    let partial = partial.unwrap();
    let bytes = partial.config_bytes();
    println!(
        "extract:   {:.2} GB/s ({} KiB partial, {iters} iters, {:?})",
        rate(bytes, iters, t0.elapsed()),
        bytes / 1024,
        t0.elapsed()
    );

    let t0 = Instant::now();
    for _ in 0..iters {
        let moved = relocate(&fp.device, &partial, &fp.regions[0], &fp.regions[2]).unwrap();
        std::hint::black_box(&moved);
    }
    let reloc_rate = rate(bytes, iters, t0.elapsed());
    println!("relocate:  {reloc_rate:.2} GB/s");

    let t0 = Instant::now();
    for _ in 0..iters {
        let mut cfg = full.clone();
        merge(&mut cfg, &partial).unwrap();
        std::hint::black_box(&cfg);
    }
    println!("merge:     {:.2} GB/s (incl. full-image clone)", rate(full.config_bytes(), iters, t0.elapsed()));

    let t0 = Instant::now();
    let mut blob = Vec::new();
    for _ in 0..iters {
        blob = partial.to_bytes();
    }
    println!("serialise: {:.2} GB/s", rate(blob.len(), iters, t0.elapsed()));

    let t0 = Instant::now();
    for _ in 0..iters {
        let b = Bitstream::from_bytes(&blob).unwrap();
        std::hint::black_box(&b);
    }
    println!("parse+crc: {:.2} GB/s", rate(blob.len(), iters, t0.elapsed()));

    assert!(reloc_rate > 1.0, "relocation below the 1 GB/s target: {reloc_rate:.2}");
}
