//! Fig 24 (beyond the paper): admission-path throughput.  Batched
//! tenant-aware admission (the daemon's pipeline: whole backlogs
//! eligible at once, weighted DRR ingest) vs per-RPC blocking dispatch
//! (one request in flight per tenant, one admission per round — the
//! classic submit→wait client), swept from 1 to 32 tenants on the
//! Ultra96.  Reports virtual requests/second and p50/p99 ticket
//! latency (request turnaround), and emits the machine-readable
//! `BENCH_fig24_admission_throughput.json` for the CI regression gate.
//! The hard comparison (batched strictly beats per-RPC) is asserted by
//! `batched_admission_beats_per_rpc_dispatch_on_throughput` in
//! `sched/sim.rs` — this program measures the margin.

use fos::accel::Catalog;
use fos::json::{b, f, obj, Value};
use fos::metrics::{percentile_ns, throughput_rps, Table};
use fos::sched::{
    simulate, AdmissionConfig, JobSpec, Policy, QosClass, SimConfig, SimResult, Workload,
};
use fos::shell::ShellBoard;

/// A burst mix: every tenant submits `reqs` requests of 4 tiles at
/// t=0, rotating over four accelerators so reuse/replication behave
/// as in a real multi-tenant daemon.
fn burst_mix(tenants: usize, reqs: usize) -> Workload {
    const ACCELS: [&str; 4] = ["mandelbrot", "sobel", "dct", "fir"];
    let mut w = Workload::new();
    for t in 0..tenants {
        for j in JobSpec::frame(t, ACCELS[t % ACCELS.len()], 0, reqs * 4, reqs) {
            w.push(j);
        }
    }
    w
}

struct Arm {
    rps: f64,
    mean_ns: f64,
    p50_ns: u64,
    p99_ns: u64,
}

fn measure(catalog: &Catalog, w: &Workload, cfg: &SimConfig) -> (SimResult, Arm) {
    let r = simulate(catalog, w, cfg);
    let turnarounds: Vec<u64> = w
        .jobs
        .iter()
        .zip(&r.job_completion)
        .map(|(j, &done)| done.saturating_sub(j.arrival))
        .collect();
    let arm = Arm {
        rps: throughput_rps(w.total_requests(), r.makespan),
        mean_ns: turnarounds.iter().sum::<u64>() as f64 / turnarounds.len().max(1) as f64,
        p50_ns: percentile_ns(&turnarounds, 50.0),
        p99_ns: percentile_ns(&turnarounds, 99.0),
    };
    (r, arm)
}

fn arm_json(a: &Arm) -> Value {
    obj(vec![
        ("reqs_per_sec", f(a.rps)),
        ("mean_turnaround_ns", f(a.mean_ns)),
        ("p50_ns", f(a.p50_ns as f64)),
        ("p99_ns", f(a.p99_ns as f64)),
    ])
}

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let tenant_counts: &[usize] = if fos::testutil::bench_smoke() {
        &[1, 4, 8]
    } else {
        &[1, 2, 4, 8, 16, 32]
    };
    let reqs = fos::testutil::bench_scale(24, 8);

    let mut t = Table::new(
        "Fig 24 — batched tenant-aware admission vs per-RPC blocking dispatch (Ultra96)",
        &[
            "tenants",
            "batched req/s",
            "per-RPC req/s",
            "speedup",
            "batched p50/p99 (ms)",
            "per-RPC p50/p99 (ms)",
        ],
    );
    let mut configs = Vec::new();
    for &tenants in tenant_counts {
        let w = burst_mix(tenants, reqs);
        let (_, batched) = measure(
            &catalog,
            &w,
            &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic),
        );
        // The per-RPC baseline: a strictly blocking client per tenant
        // (in-flight quota 1) and one admission per scheduling round.
        let w_rpc = w.clone().with_uniform_qos(QosClass::new(1, 1));
        let (_, per_rpc) = measure(
            &catalog,
            &w_rpc,
            &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic)
                .with_admission(AdmissionConfig::per_rpc()),
        );
        t.row(&[
            tenants.to_string(),
            format!("{:.0}", batched.rps),
            format!("{:.0}", per_rpc.rps),
            format!("{:.2}x", batched.rps / per_rpc.rps.max(1e-9)),
            format!(
                "{:.2}/{:.2}",
                batched.p50_ns as f64 / 1e6,
                batched.p99_ns as f64 / 1e6
            ),
            format!(
                "{:.2}/{:.2}",
                per_rpc.p50_ns as f64 / 1e6,
                per_rpc.p99_ns as f64 / 1e6
            ),
        ]);
        configs.push((
            format!("tenants_{tenants}"),
            obj(vec![
                ("batched", arm_json(&batched)),
                ("per_rpc", arm_json(&per_rpc)),
            ]),
        ));
    }
    t.print();
    println!(
        "batched admission keeps the whole fabric busy; a blocking per-RPC client caps \
         concurrency at one request per tenant (asserted in sched/sim.rs)."
    );

    // Machine-readable result for the CI bench-regression gate — the
    // mean_turnaround_ns leaves are deterministic virtual-time numbers.
    let doc = obj(vec![
        ("bench", fos::json::s("fig24_admission_throughput")),
        ("smoke", b(fos::testutil::bench_smoke())),
        (
            "configs",
            Value::Object(configs.into_iter().collect()),
        ),
    ]);
    match fos::testutil::write_bench_json("fig24_admission_throughput", &doc) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
