//! Fig 23 (extension): cluster scaling — the fig22 multi-tenant mix
//! sharded over 1→8 boards (alternating Ultra96/ZCU102, the paper's
//! two evaluation platforms) under each placement policy.
//!
//! The claim under test: **locality-aware placement beats blind
//! round-robin on both reconfiguration count and mean turnaround once
//! the cluster has ≥4 boards** — scattering a tenant's requests over
//! every board makes every board reload every accelerator, while
//! bitstream-affinity routing amortises one load per accelerator per
//! home board (work stealing keeps the tail balanced).  All numbers
//! are virtual-time (deterministic), so the emitted
//! `BENCH_fig23_cluster_scaling.json` is regression-gateable in CI.

use fos::accel::Catalog;
use fos::json::{b, f, i, obj, s, Value};
use fos::metrics::{cluster_summary, Table};
use fos::sched::{
    cluster_mean_turnaround_ns, simulate_cluster, ClusterSimConfig, ClusterSimResult,
    PlacementKind, Policy, Workload,
};
use fos::shell::ShellBoard;

fn boards(n: usize) -> Vec<ShellBoard> {
    (0..n)
        .map(|k| if k % 2 == 0 { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
        .collect()
}

fn run(catalog: &Catalog, w: &Workload, n: usize, kind: PlacementKind) -> ClusterSimResult {
    simulate_cluster(catalog, w, &ClusterSimConfig::new(boards(n), Policy::Elastic, kind))
}

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    // The multi-tenant mix: 8 tenants over 8 accelerators, staggered
    // request waves (see Workload::cluster_mix) — fig22's concurrency
    // scenario widened to exercise cross-board placement.
    let waves = fos::testutil::bench_scale(6, 4);
    let w = Workload::cluster_mix(8, waves, 3, 8, 400_000);
    let kinds =
        [PlacementKind::RoundRobin, PlacementKind::LeastLoaded, PlacementKind::Locality];

    let mut t = Table::new(
        format!(
            "Fig 23 — cluster scaling, {} tenants x {} waves, Ultra96/ZCU102 alternating",
            8, waves
        ),
        &[
            "boards",
            "policy",
            "mean turnaround (ms)",
            "makespan (ms)",
            "reconfigs",
            "reuses",
            "steals",
        ],
    );
    let mut sweep_entries: Vec<Value> = Vec::new();
    let mut at4: Vec<(PlacementKind, u64, f64)> = Vec::new(); // (kind, reconfigs, mean)
    for n in [1usize, 2, 4, 6, 8] {
        let mut policy_fields: Vec<(&str, Value)> = Vec::new();
        for kind in kinds {
            let r = run(&catalog, &w, n, kind);
            let mean_ns = cluster_mean_turnaround_ns(&w, &r);
            let reconfigs = r.total_reconfigs();
            let reuses: u64 = r.boards.iter().map(|x| x.counters.reuses).sum();
            t.row(&[
                n.to_string(),
                kind.name().into(),
                format!("{:.2}", mean_ns / 1e6),
                format!("{:.2}", r.makespan as f64 / 1e6),
                reconfigs.to_string(),
                reuses.to_string(),
                r.cluster.steals.to_string(),
            ]);
            if n == 4 {
                at4.push((kind, reconfigs, mean_ns));
                let per_board: Vec<(String, fos::sched::SchedCounters)> = r
                    .boards
                    .iter()
                    .enumerate()
                    .map(|(k, x)| (format!("board{k} ({})", x.board.name()), x.counters.clone()))
                    .collect();
                println!("{}", cluster_summary(&format!("{} x4 boards", kind.name()), &per_board));
            }
            policy_fields.push((
                kind.name(),
                obj(vec![
                    ("mean_turnaround_ns", f(mean_ns)),
                    ("reconfigs", f(reconfigs as f64)),
                    ("preemptions", f(r.total_preemptions() as f64)),
                    ("steals", f(r.cluster.steals as f64)),
                ]),
            ));
        }
        sweep_entries.push(obj(vec![
            ("boards", i(n as i64)),
            ("placements", obj(policy_fields)),
        ]));
    }
    t.print();

    // The headline comparison (the acceptance claim, also asserted by
    // the simulator's locality_beats_round_robin_at_four_boards test).
    let rr = at4.iter().find(|(k, _, _)| *k == PlacementKind::RoundRobin).unwrap();
    let loc = at4.iter().find(|(k, _, _)| *k == PlacementKind::Locality).unwrap();
    println!(
        "at 4 boards: locality {} reconfigs vs round-robin {} ({:.0}% fewer); \
         mean turnaround {:.2} ms vs {:.2} ms ({:.0}% lower)",
        loc.1,
        rr.1,
        100.0 * (1.0 - loc.1 as f64 / rr.1.max(1) as f64),
        loc.2 / 1e6,
        rr.2 / 1e6,
        100.0 * (1.0 - loc.2 / rr.2.max(1.0)),
    );

    let doc = obj(vec![
        ("bench", s("fig23_cluster_scaling")),
        ("smoke", b(fos::testutil::bench_smoke())),
        ("sweep", fos::json::arr(sweep_entries)),
    ]);
    match fos::testutil::write_bench_json("fig23_cluster_scaling", &doc) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
