//! §Perf microbench: scheduling-round latency and decision throughput
//! on the hot path — the virtual-time drain loop the simulator and the
//! daemon dispatcher both drive.
//!
//! Two sweeps, both deterministic in decision content (only the
//! wall-clock numbers vary by machine):
//!
//! * **single shard** — one `SchedCore`, queue depths 1k → 100k
//!   requests from 8 users over a mixed accelerator set; measures
//!   decisions per wall-second and the p99 per-round latency.
//! * **cluster** — the same mix through `ClusterCore` at 1 → 8 boards.
//!
//! Emits `BENCH_perf_round_latency.json` with a top-level
//! `single_shard_decisions_per_sec` leaf (the peak across the depth
//! sweep).  `scripts/check_bench_regression.py` enforces a throughput
//! *floor* on that leaf — wall-clock rates are machine-dependent, so
//! the gate is a floor, not a baseline comparison.

use fos::accel::Catalog;
use fos::json::{arr, b, f, i, obj, s, Value};
use fos::sched::{ClusterCore, DecisionKind, PlacementKind, Policy, SchedCore};
use fos::shell::{Shell, ShellBoard};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

const USERS: usize = 8;
const ACCELS: [&str; 8] =
    ["vadd", "mm", "fir", "histogram", "dct", "sobel", "mandelbrot", "black_scholes"];

fn p99(mut xs: Vec<u64>) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    xs.sort_unstable();
    xs[(xs.len() * 99 / 100).min(xs.len() - 1)]
}

/// Drain one pre-filled core in virtual time, timing each scheduling
/// round with a wall clock.  Returns (decisions, elapsed_s, p99_ns).
fn drain_core(core: &mut SchedCore) -> (u64, f64, u64) {
    let mut now = 0u64;
    let mut completions: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut round_ns: Vec<u64> = Vec::new();
    let mut decisions = 0u64;
    let t0 = Instant::now();
    loop {
        let r0 = Instant::now();
        core.begin_round_at(now);
        while let Some(d) = core.next_decision() {
            decisions += 1;
            if d.kind != DecisionKind::Preempt {
                let lat = core.service_ns(&d, core.busy_anchors().saturating_sub(1));
                let end = now + lat.max(1);
                core.mark_running(&d, now, end);
                completions.push(Reverse((end, d.anchor)));
            }
        }
        round_ns.push(r0.elapsed().as_nanos() as u64);
        match completions.pop() {
            Some(Reverse((end, anchor))) => {
                now = now.max(end);
                core.complete(anchor);
            }
            None => {
                if !core.has_pending() {
                    break;
                }
                // Nothing running and nothing placeable would be a
                // livelock; the mixed elastic workload never gets here.
                now += 1;
            }
        }
    }
    (decisions, t0.elapsed().as_secs_f64(), p99(round_ns))
}

fn fill_core(core: &mut SchedCore, depth: usize) {
    for j in 0..depth as u64 {
        let u = (j as usize) % USERS;
        let accel = ACCELS[(j as usize) % ACCELS.len()];
        let tiles = 1 + (j as usize) % 3;
        core.submit(u, j, accel, tiles, None).unwrap();
    }
}

fn boards(n: usize) -> Vec<ShellBoard> {
    (0..n)
        .map(|k| if k % 2 == 0 { ShellBoard::Ultra96 } else { ShellBoard::Zcu102 })
        .collect()
}

/// The cluster drain: every board rounds at each virtual-time step.
fn drain_cluster(cluster: &mut ClusterCore, n: usize) -> (u64, f64, u64) {
    let mut now = 0u64;
    let mut completions: BinaryHeap<Reverse<(u64, usize, usize)>> = BinaryHeap::new();
    let mut round_ns: Vec<u64> = Vec::new();
    let mut decisions = 0u64;
    let t0 = Instant::now();
    loop {
        let r0 = Instant::now();
        for board in 0..n {
            cluster.begin_round_at(board, now);
            while let Some(d) = cluster.next_decision(board) {
                decisions += 1;
                if d.kind != DecisionKind::Preempt {
                    let core = cluster.core(board);
                    let lat = core.service_ns(&d, core.busy_anchors().saturating_sub(1));
                    let end = now + lat.max(1);
                    cluster.core_mut(board).mark_running(&d, now, end);
                    completions.push(Reverse((end, board, d.anchor)));
                }
            }
        }
        round_ns.push(r0.elapsed().as_nanos() as u64);
        match completions.pop() {
            Some(Reverse((end, board, anchor))) => {
                now = now.max(end);
                cluster.complete(board, anchor);
            }
            None => {
                if !cluster.has_pending() {
                    break;
                }
                now += 1;
            }
        }
    }
    (decisions, t0.elapsed().as_secs_f64(), p99(round_ns))
}

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let smoke = fos::testutil::bench_smoke();

    // --- single shard ---------------------------------------------
    let depths: &[usize] = if smoke { &[1_000, 4_000] } else { &[1_000, 10_000, 100_000] };
    let mut single_entries: Vec<Value> = Vec::new();
    let mut peak_rate = 0.0f64;
    println!("single shard (Ultra96, Elastic), {USERS} users, {} accelerators:", ACCELS.len());
    for &depth in depths {
        let shell = Shell::build(ShellBoard::Ultra96);
        let mut core = SchedCore::new(&shell, catalog.clone(), Policy::Elastic);
        fill_core(&mut core, depth);
        let (decisions, secs, p99_ns) = drain_core(&mut core);
        let rate = decisions as f64 / secs;
        peak_rate = peak_rate.max(rate);
        println!(
            "  depth {depth:>6}: {decisions} decisions in {:.3} s -> {:.0}/s, p99 round {:.2} us",
            secs,
            rate,
            p99_ns as f64 / 1e3
        );
        single_entries.push(obj(vec![
            ("depth", i(depth as i64)),
            ("decisions", i(decisions as i64)),
            ("decisions_per_sec", f(rate)),
            ("p99_round_ns", f(p99_ns as f64)),
        ]));
    }

    // --- cluster --------------------------------------------------
    let board_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    let cluster_depth = if smoke { 4_000 } else { 20_000 };
    let mut cluster_entries: Vec<Value> = Vec::new();
    println!("cluster (Elastic, Locality), depth {cluster_depth}:");
    for &n in board_counts {
        let mut cluster =
            ClusterCore::new(&boards(n), &catalog, Policy::Elastic, PlacementKind::Locality);
        for j in 0..cluster_depth as u64 {
            let u = (j as usize) % USERS;
            let accel = ACCELS[(j as usize) % ACCELS.len()];
            cluster.submit(u, j, accel, 1 + (j as usize) % 3, None).unwrap();
        }
        let (decisions, secs, p99_ns) = drain_cluster(&mut cluster, n);
        let rate = decisions as f64 / secs;
        println!(
            "  {n} board(s): {decisions} decisions in {:.3} s -> {:.0}/s, p99 round {:.2} us",
            secs,
            rate,
            p99_ns as f64 / 1e3
        );
        cluster_entries.push(obj(vec![
            ("boards", i(n as i64)),
            ("decisions", i(decisions as i64)),
            ("decisions_per_sec", f(rate)),
            ("p99_round_ns", f(p99_ns as f64)),
        ]));
    }

    println!("peak single-shard throughput: {:.0} decisions/s", peak_rate);

    let doc = obj(vec![
        ("bench", s("perf_round_latency")),
        ("smoke", b(smoke)),
        ("single_shard_decisions_per_sec", f(peak_rate)),
        ("single_shard", arr(single_entries)),
        ("cluster", arr(cluster_entries)),
    ]);
    match fos::testutil::write_bench_json("perf_round_latency", &doc) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
