//! Fig 26 (repo-local): two-tenant memory-bandwidth interference on
//! the Ultra96 — a latency-sensitive tenant (high QoS weight, short
//! Sobel requests arriving on a period) next to a streaming tenant
//! (weight 1, long Mandelbrot batches saturating the fabric) — with
//! weighted bandwidth partitioning off vs on.
//!
//! Partitioning charges each dispatch's DMA legs at its tenant's QoS
//! share of the contended bandwidth (`DdrModel::
//! transfer_ns_partitioned`) instead of the per-master equal split:
//! the latency tenant's tail latency must stay bounded while the
//! streaming tenant saturates only its own share.  All numbers are
//! virtual-time simulator outputs — bit-for-bit deterministic, so the
//! CI floor check on `latency_p99_improvement` guards real scheduling
//! regressions, never runner noise.

use fos::accel::Catalog;
use fos::metrics::Table;
use fos::sched::{simulate, AdmissionConfig, JobSpec, Policy, QosClass, SimConfig, Workload};
use fos::shell::ShellBoard;

const LATENCY_TENANT: usize = 0;
const STREAM_TENANT: usize = 1;

fn workload(latency_jobs: usize, stream_tiles: usize) -> Workload {
    let mut w = Workload::new();
    // Latency tenant: short pinned Sobel frames on a fixed period.
    for k in 0..latency_jobs {
        w.push(JobSpec::stream(
            LATENCY_TENANT,
            "sobel",
            Some("sobel_v1"),
            k as u64 * 40_000,
            2,
        ));
    }
    // Streaming tenant: two long Mandelbrot batches from t=0 — two of
    // the Ultra96's three PR regions stay stream-held while the third
    // serves the latency tenant, so the two tenants genuinely contend
    // for DDR bandwidth the whole run.
    for _ in 0..2 {
        w.push(JobSpec::stream(
            STREAM_TENANT,
            "mandelbrot",
            Some("mandelbrot_v1"),
            0,
            stream_tiles,
        ));
    }
    w.set_qos(LATENCY_TENANT, QosClass::new(4, usize::MAX));
    w.set_qos(STREAM_TENANT, QosClass::new(1, usize::MAX));
    w
}

/// Per-tenant turnaround samples (virtual ns), workload order.
fn turnarounds(w: &Workload, completion: &[u64], tenant: usize) -> Vec<f64> {
    w.jobs
        .iter()
        .zip(completion)
        .filter(|(j, _)| j.user == tenant)
        .map(|(j, &c)| c.saturating_sub(j.arrival) as f64)
        .collect()
}

fn percentile(samples: &mut [f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() - 1) as f64 * p).round() as usize;
    samples[idx]
}

struct RunStats {
    latency_p50_us: f64,
    latency_p99_us: f64,
    stream_makespan_ms: f64,
}

fn run(catalog: &Catalog, w: &Workload, partition: bool) -> RunStats {
    let admission = if partition {
        AdmissionConfig::default().with_bw_partition()
    } else {
        AdmissionConfig::default()
    };
    let cfg = SimConfig::new(ShellBoard::Ultra96, Policy::Elastic).with_admission(admission);
    let r = simulate(catalog, w, &cfg);
    let mut lat = turnarounds(w, &r.job_completion, LATENCY_TENANT);
    let stream_done = w
        .jobs
        .iter()
        .zip(&r.job_completion)
        .filter(|(j, _)| j.user == STREAM_TENANT)
        .map(|(_, &c)| c)
        .max()
        .unwrap_or(0);
    RunStats {
        latency_p50_us: percentile(&mut lat, 0.50) / 1e3,
        latency_p99_us: percentile(&mut lat, 0.99) / 1e3,
        stream_makespan_ms: stream_done as f64 / 1e6,
    }
}

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let latency_jobs = fos::testutil::bench_scale(200, 50);
    let stream_tiles = fos::testutil::bench_scale(240, 80);
    let w = workload(latency_jobs, stream_tiles);

    let off = run(&catalog, &w, false);
    let on = run(&catalog, &w, true);

    let mut t = Table::new(
        format!(
            "Fig 26 — bandwidth partitioning: {latency_jobs} short Sobel (weight 4) vs \
             2x{stream_tiles}-tile Mandelbrot streams (weight 1), Ultra96"
        ),
        &["partition", "latency p50 (us)", "latency p99 (us)", "stream makespan (ms)"],
    );
    for (name, s) in [("off (equal split)", &off), ("on (QoS share)", &on)] {
        t.row(&[
            name.into(),
            format!("{:.1}", s.latency_p50_us),
            format!("{:.1}", s.latency_p99_us),
            format!("{:.2}", s.stream_makespan_ms),
        ]);
    }
    t.print();

    let p99_improvement = if on.latency_p99_us > 0.0 {
        off.latency_p99_us / on.latency_p99_us
    } else {
        1.0
    };
    println!(
        "latency-tenant p99: {:.1} us -> {:.1} us ({p99_improvement:.2}x); \
         streaming tenant pays for its own fan-out ({:.2} ms -> {:.2} ms)",
        off.latency_p99_us, on.latency_p99_us, off.stream_makespan_ms, on.stream_makespan_ms,
    );

    // Machine-readable result for the CI floor gate: partitioning must
    // keep the latency tenant's p99 bounded (improvement ratio floor —
    // deterministic virtual time, so any dip is a model regression).
    use fos::json::{b, f, obj, s};
    let doc = obj(vec![
        ("bench", s("fig26_bw_interference")),
        ("smoke", b(fos::testutil::bench_smoke())),
        ("latency_p99_improvement", f(p99_improvement)),
        ("latency_p99_us_equal_split", f(off.latency_p99_us)),
        ("latency_p99_us_partitioned", f(on.latency_p99_us)),
        ("latency_p50_us_partitioned", f(on.latency_p50_us)),
        ("stream_makespan_ms_equal_split", f(off.stream_makespan_ms)),
        ("stream_makespan_ms_partitioned", f(on.stream_makespan_ms)),
    ]);
    match fos::testutil::write_bench_json("fig26_bw_interference", &doc) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
