//! Table 3: P&R + bitgen latency, Xilinx PR flow vs FOS decoupled flow,
//! for the three module densities, compiling for all 3 Ultra96 regions.
//! Also demonstrates FOS's flat scaling vs Xilinx's linear scaling in
//! the number of regions.

use fos::fabric::{Device, DeviceKind, Floorplan, Resources};
use fos::metrics::Table;
use fos::pnr::{compile_fos, compile_xilinx_pr, CostModel, Netlist};

fn workload(name: &str, util: f64) -> Netlist {
    Netlist::synthesize(
        name,
        &Resources {
            luts: (17760.0 * util) as usize,
            ffs: (35520.0 * util * 0.9) as usize,
            brams: (72.0 * util * 0.4) as usize,
            dsps: (120.0 * util * 0.3) as usize,
        },
    )
}

fn main() {
    let fp = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
    let model = CostModel::default();
    // (name, util, paper: xil P&R, xil bitgen, fos P&R, fos bitgen, speedup)
    let rows = [
        ("AES", 0.33, 429.40, 176.19, 284.18, 64.06, 1.74),
        ("Normal Est.", 0.63, 747.75, 201.21, 387.41, 70.09, 2.07),
        ("Black Scholes", 0.81, 1296.26, 231.27, 574.56, 77.11, 2.34),
    ];
    let mut t = Table::new(
        "Table 3 — compile-for-3-regions latency, measured (paper), seconds",
        &["module", "util", "Xilinx P&R", "Xilinx bitgen", "FOS P&R", "FOS bitgen", "speedup"],
    );
    for (name, util, px, pxb, pf, pfb, psp) in rows {
        let nl = workload(name, util);
        let xil = compile_xilinx_pr(&fp, &nl, &model).unwrap();
        let fos = compile_fos(&fp, &nl, &model).unwrap();
        let speedup = xil.total_seconds() / fos.total_seconds();
        t.row(&[
            name.into(),
            format!("{:.0}%", util * 100.0),
            format!("{:.1} ({px})", xil.pnr_seconds),
            format!("{:.1} ({pxb})", xil.bitgen_seconds),
            format!("{:.1} ({pf})", fos.pnr_seconds),
            format!("{:.1} ({pfb})", fos.bitgen_seconds),
            format!("{speedup:.2}x ({psp}x)"),
        ]);
    }
    t.print();

    // Scaling in region count: FOS flat, Xilinx linear.
    let nl = workload("AES", 0.33);
    let mut t2 = Table::new(
        "compile latency vs number of PR regions (AES)",
        &["regions", "Xilinx total (s)", "FOS total (s)"],
    );
    for n in 1..=3 {
        let mut fpn = Floorplan::standard(Device::new(DeviceKind::Zu3eg));
        fpn.regions.truncate(n);
        let xil = compile_xilinx_pr(&fpn, &nl, &model).unwrap();
        let fos = compile_fos(&fpn, &nl, &model).unwrap();
        t2.row(&[
            n.to_string(),
            format!("{:.1}", xil.total_seconds()),
            format!("{:.1}", fos.total_seconds()),
        ]);
    }
    t2.print();
}
