//! Table 1: resources available for acceleration on ZCU102 and
//! Ultra96/UltraZed. Regenerates the paper's rows from the shell
//! builder's floorplan accounting.

use fos::metrics::Table;
use fos::shell::{Shell, ShellBoard};

fn main() {
    // Paper values for the comparison columns.
    let paper_zcu = [
        ("CLB LUTs", 32640, 11.70, 46.80),
        ("CLB Regs.", 65280, 11.90, 47.60),
        ("BRAMs", 108, 12.10, 48.40),
        ("DSPs", 336, 13.30, 53.20),
    ];
    let paper_u96: [(&str, usize, f64, f64); 1] = [("CLB LUTs", 17760, 25.17, 75.51)];

    for (board, paper) in [
        (ShellBoard::Zcu102, &paper_zcu[..]),
        (ShellBoard::Ultra96, &paper_u96[..]),
        (ShellBoard::UltraZed, &paper_u96[..]),
    ] {
        let shell = Shell::build(board);
        let t1 = shell.table1();
        let measured = [
            ("CLB LUTs", t1.region.luts, t1.per_region_pct[0], t1.total_pct[0]),
            ("CLB Regs.", t1.region.ffs, t1.per_region_pct[1], t1.total_pct[1]),
            ("BRAMs", t1.region.brams, t1.per_region_pct[2], t1.total_pct[2]),
            ("DSPs", t1.region.dsps, t1.per_region_pct[3], t1.total_pct[3]),
        ];
        let mut t = Table::new(
            format!(
                "Table 1 — {} ({} PR regions)",
                shell.board.name(),
                shell.region_count()
            ),
            &[
                "resource",
                "per region (paper)",
                "chip % / region (paper)",
                "chip % total (paper)",
            ],
        );
        for row in measured {
            let p = paper.iter().find(|p| p.0 == row.0);
            let fmt = |m: String, pp: Option<String>| match pp {
                Some(pp) => format!("{m} ({pp})"),
                None => format!("{m} (-)"),
            };
            t.row(&[
                row.0.to_string(),
                fmt(row.1.to_string(), p.map(|p| p.1.to_string())),
                fmt(format!("{:.2}", row.2), p.map(|p| format!("{:.2}", p.2))),
                fmt(format!("{:.2}", row.3), p.map(|p| format!("{:.2}", p.3))),
            ]);
        }
        t.print();
        let stat = shell.floorplan.static_resources();
        println!(
            "static shell remainder: {} LUTs / {} FFs / {} BRAMs / {} DSPs",
            stat.luts, stat.ffs, stat.brams, stat.dsps
        );
    }
}
