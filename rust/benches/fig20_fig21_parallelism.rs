//! Figs 20–21: Mandelbrot, Black-Scholes and Sobel on the Ultra96 with
//! varying numbers of acceleration requests per frame — absolute
//! latencies (Fig 20) and latencies relative to 1 request (Fig 21).
//! Expect near-linear gains up to 3 requests (= PR regions), stagnation
//! beyond, and multiples of 3 doing better than non-multiples.

use fos::accel::Catalog;
use fos::metrics::Table;
use fos::sched::{simulate, JobSpec, Policy, SimConfig, Workload};
use fos::shell::ShellBoard;

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    // (accel, pinned 1-region variant, tiles per frame)
    let apps = [
        ("mandelbrot", "mandelbrot_v1", 12usize),
        ("black_scholes", "black_scholes_v1", 12),
        ("sobel", "sobel_v1", 12),
    ];
    let requests = [1usize, 2, 3, 4, 5, 6, 8, 9, 12];

    let mut abs = Table::new(
        "Fig 20 — execution latency (ms) vs exposed requests (Ultra96, 3 regions)",
        &["requests", "mandelbrot", "black_scholes", "sobel"],
    );
    let mut rel = Table::new(
        "Fig 21 — latency relative to 1 request",
        &["requests", "mandelbrot", "black_scholes", "sobel"],
    );
    let mut bases = [0f64; 3];
    for &reqs in &requests {
        let mut abs_row = vec![reqs.to_string()];
        let mut rel_row = vec![reqs.to_string()];
        for (k, (accel, variant, tiles)) in apps.iter().enumerate() {
            let mut w = Workload::new();
            for j in JobSpec::frame_pinned(0, accel, variant, 0, *tiles, reqs) {
                w.push(j);
            }
            let r = simulate(
                &catalog,
                &w,
                &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic),
            );
            let ms = r.makespan as f64 / 1e6;
            if reqs == 1 {
                bases[k] = ms;
            }
            abs_row.push(format!("{ms:.2}"));
            rel_row.push(format!("{:.2}", ms / bases[k]));
        }
        abs.row(&abs_row);
        rel.row(&rel_row);
    }
    abs.print();
    rel.print();
    println!("paper shape: near-linear to 3 requests, stagnation past the region count,");
    println!("multiples of 3 avoid leftover-request bottlenecks; sobel (memory-bound)");
    println!("gains least — its latency is DDR transfer, not compute.");
}
