//! §Perf microbench: scheduler decision throughput and DES engine rate.
//! Target: decision cost ≤ 20 µs (paper Table 4: 0.02 ms scheduler).

use fos::accel::Catalog;
use fos::sched::{simulate, JobSpec, Policy, SimConfig, Workload};
use fos::shell::ShellBoard;
use std::time::Instant;

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    // A heavy mixed workload: 8 users x 64 requests.
    let mut w = Workload::new();
    let accels = ["vadd", "mm", "fir", "histogram", "dct", "sobel", "mandelbrot", "black_scholes"];
    for (u, accel) in accels.iter().enumerate() {
        for j in JobSpec::frame(u, accel, (u as u64) * 100_000, 64, 64) {
            w.push(j);
        }
    }
    let total_requests = w.total_requests();

    for policy in [Policy::Elastic, Policy::Fixed] {
        let t0 = Instant::now();
        let iters = fos::testutil::bench_scale(20, 2);
        let mut makespan = 0;
        for _ in 0..iters {
            let r = simulate(&catalog, &w, &SimConfig::new(ShellBoard::Zcu102, policy));
            makespan = r.makespan;
        }
        let el = t0.elapsed();
        let per_req = el.as_secs_f64() / (iters * total_requests) as f64;
        println!(
            "{policy:?}: {} requests simulated {iters}x in {el:?} -> {:.2} us per scheduled request (virtual makespan {:.1} ms)",
            total_requests,
            per_req * 1e6,
            makespan as f64 / 1e6
        );
        assert!(
            per_req * 1e6 < 20.0,
            "scheduling cost {:.2} us exceeds the 20 us target",
            per_req * 1e6
        );
    }
}
