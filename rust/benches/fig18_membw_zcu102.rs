//! Fig 18: memory throughput vs burst size on the ZCU102's duplex AXI
//! HP ports (HP0–HP3), individually and all together — including the
//! sub-linear multi-port scaling from row pollution.

use fos::memsim::{config_for, DdrModel, PortLoad};
use fos::metrics::Table;
use fos::shell::ShellBoard;

fn main() {
    let m = DdrModel::new(config_for(ShellBoard::Zcu102));
    let mut t = Table::new(
        "Fig 18 — ZCU102 AXI throughput vs burst size (MB/s)",
        &["burst (B)", "read/port", "write/port", "1 port total", "2 ports", "4 ports total"],
    );
    for burst in [16u32, 32, 64, 128, 256, 512, 1024, 2048, 4096] {
        let one = m.steady_state(&[PortLoad::duplex(burst)]);
        let two = m.steady_state(&[PortLoad::duplex(burst); 2]);
        let all = m.steady_state(&[PortLoad::duplex(burst); 4]);
        t.row(&[
            burst.to_string(),
            format!("{:.0}", one.per_port_dir_mbps[0].0),
            format!("{:.0}", one.per_port_dir_mbps[0].1),
            format!("{:.0}", one.total_mbps),
            format!("{:.0}", two.total_mbps),
            format!("{:.0}", all.total_mbps),
        ]);
    }
    t.print();
    let one = m.steady_state(&[PortLoad::duplex(1024)]);
    let all = m.steady_state(&[PortLoad::duplex(1024); 4]);
    println!("paper: ~1600 MB/s per direction, 3200 MB/s per port, 8804 MB/s all four");
    println!(
        "measured @1KiB: {:.0} per direction, {:.0} per port, {:.0} all four ({:.2}x of 4x-linear — sub-linear from row pollution + controller multiplexing)",
        one.per_port_dir_mbps[0].0,
        one.total_mbps,
        all.total_mbps,
        all.total_mbps / (4.0 * one.total_mbps)
    );
}
