//! Table 2: bus-virtualisation resource overhead at the logical and
//! physical levels, for the paper's two adaptor configurations.

use fos::metrics::Table;
use fos::shell::{AxiInterface, BusAdaptor, WrapMode};

fn main() {
    let configs = [
        (
            "32b AXI-Lite & 128b AXI4 Master",
            "AXI Interconnect",
            AxiInterface::Master { bits: 32 },
            // paper logical (LUT, FF, BRAM)
            (153, 284, 0.0),
        ),
        (
            "32b AXI-Lite & 128b AXI4 Master",
            "Ctrl reg., AXI MM2S & AXI DMA",
            AxiInterface::Stream { bits: 32, has_dma: false },
            (1952, 2694, 2.5),
        ),
    ];
    let mut t = Table::new(
        "Table 2 — bus adaptor overhead, measured (paper)",
        &["shell interface", "services", "primitive", "logical", "physical"],
    );
    for (iface, services, module_if, paper) in configs {
        let a = BusAdaptor::for_interface(module_if, WrapMode::Runtime).unwrap();
        let logical = a.logical_resources();
        let phys = a.physical_resources();
        t.row(&[
            iface.into(),
            services.into(),
            "LUTs".into(),
            format!("{} ({})", logical.luts, paper.0),
            format!("{} (2400)", phys.luts),
        ]);
        t.row(&[
            "".into(),
            "".into(),
            "FFs".into(),
            format!("{} ({})", logical.ffs, paper.1),
            format!("{} (4800)", phys.ffs),
        ]);
        t.row(&[
            "".into(),
            "".into(),
            "BRAMs".into(),
            format!("{} ({})", a.logical_brams_frac(), paper.2),
            format!("{} (12)", phys.brams),
        ]);
    }
    t.print();
    let dense = BusAdaptor::for_interface(
        AxiInterface::Stream { bits: 32, has_dma: false },
        WrapMode::Runtime,
    )
    .unwrap();
    println!(
        "pre-allocation waste for the dense config: {} LUTs ({:.0}%) — paper: 448 LUTs (18%)",
        dense.prealloc_waste_luts(),
        100.0 * dense.prealloc_waste_luts() as f64 / 2400.0
    );
}
