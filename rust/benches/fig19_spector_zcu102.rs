//! Fig 19: execution latency of Spector-suite accelerators on the
//! ZCU102 as the number of PR regions available for acceleration grows
//! 1 → 4. Near-linear scaling for most; super-linear for DCT via its
//! 2-region implementation alternative (3.55x at 2x resources).

use fos::accel::Catalog;
use fos::metrics::Table;
use fos::sched::{simulate, JobSpec, Policy, SimConfig, Workload};
use fos::shell::ShellBoard;

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let accels = ["mm", "fir", "histogram", "dct", "normal_est", "sobel"];
    let tiles = 240usize; // one "input set" = 240 work items (Spector runs are long)

    let mut t = Table::new(
        "Fig 19 — Spector on ZCU102: latency (ms) vs regions [speedup vs 1]",
        &["accelerator", "1 region", "2 regions", "3 regions", "4 regions"],
    );
    for accel in accels {
        let mut cells = vec![accel.to_string()];
        let mut base = None;
        for regions in 1..=4usize {
            let mut w = Workload::new();
            // Expose as many requests as regions (paper's best case).
            for j in JobSpec::frame(0, accel, 0, tiles, regions * 2) {
                w.push(j);
            }
            let r = simulate(
                &catalog,
                &w,
                &SimConfig::new(ShellBoard::Zcu102, Policy::Elastic).with_regions(regions),
            );
            let ms = r.makespan as f64 / 1e6;
            let b = *base.get_or_insert(ms);
            cells.push(format!("{ms:.2} [{:.2}x]", b / ms));
        }
        t.row(&cells);
    }
    t.print();

    // Verify the DCT super-linear claim explicitly.
    let dct_speedup_2x = {
        let run = |regions: usize| {
            let mut w = Workload::new();
            for j in JobSpec::frame(0, "dct", 0, tiles, regions * 2) {
                w.push(j);
            }
            simulate(
                &catalog,
                &w,
                &SimConfig::new(ShellBoard::Zcu102, Policy::Elastic).with_regions(regions),
            )
            .makespan as f64
        };
        run(1) / run(2)
    };
    println!(
        "DCT at 2x resources: {dct_speedup_2x:.2}x speedup (paper: 3.55x, super-linear via the bigger implementation)"
    );
    assert!(dct_speedup_2x > 2.0, "DCT must be super-linear");
}
