//! Fig 25: connection scaling of the daemon's reactor network plane.
//!
//! Sweeps 1k → 100k concurrent sessions (1k → 20k in smoke mode)
//! against a live daemon — once with a single reactor shard and once
//! with N shards behind the dedicated acceptor
//! (`DaemonConfig::reactor_shards`) — each session issuing ping RPCs
//! over its own Unix-domain connection, measuring requests/second,
//! p99 round-trip latency and peak resident memory.  RLIMIT_NOFILE is
//! raised to its hard cap in-bench; levels past the resulting fd
//! budget are clamped with a logged note.  A faithful in-bench
//! reproduction of the pre-reactor architecture — one blocking thread
//! per connection bridging to a dispatcher channel — is measured at
//! 1k sessions as the baseline, and *skipped with a logged note*
//! (never a silent pass, never an abort of the sweep) when the fd or
//! thread budget cannot cover even that.
//!
//! The client driver is itself a single multiplexed non-blocking event
//! loop built on the public `fos::daemon::transport` poller/framing
//! types, so a 100k-session sweep costs 100k sockets, not 100k
//! threads, and the reactor daemon (at any shard count) and the
//! thread-per-connection baseline are driven identically.
//!
//! Emits `BENCH_fig25_connection_scaling.json` with three floor-gated
//! leaves (`scripts/check_bench_regression.py`):
//!
//! * `sessions_sustained` — every session of the largest sweep level
//!   connected and completed its full ping schedule
//!   (floor: 100 000 full / 20 000 smoke);
//! * `nshard_vs_1shard_ratio` — max sessions sustained by the N-shard
//!   plane divided by the single shard's (floor: 1.0 — sharding must
//!   never sustain fewer sessions than one reactor);
//! * `reactor_vs_thread_ratio` — single-shard reactor requests/sec at
//!   the largest level divided by the thread-per-connection baseline's
//!   at 1k (floor: the reactor must not be slower than the
//!   architecture it replaced, despite serving 100x the sessions).

use fos::accel::Catalog;
use fos::daemon::transport::{Events, FrameBuf, Poller};
use fos::daemon::{read_msg, write_msg, Daemon, DaemonConfig};
use fos::json::{arr, b, f, i, obj, s, Value};
use fos::shell::ShellBoard;
use std::io::{ErrorKind, Read, Write};
use std::os::fd::AsRawFd;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Raise RLIMIT_NOFILE to its hard cap (two fds per session live in
/// this one process: the client socket and the daemon's accepted end).
/// Returns the resulting soft limit.
#[cfg(target_os = "linux")]
fn raise_nofile_limit() -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return 1024;
        }
        if r.cur < r.max {
            let want = RLimit { cur: r.max, max: r.max };
            let _ = setrlimit(RLIMIT_NOFILE, &want);
            if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
                return 1024;
            }
        }
        r.cur
    }
}

#[cfg(not(target_os = "linux"))]
fn raise_nofile_limit() -> u64 {
    1024
}

/// Peak resident set (VmHWM) of this process in bytes; 0 when /proc is
/// unavailable.  Both the daemon and the bench driver live in this
/// process, so this is the whole experiment's memory high-water mark.
fn peak_rss_bytes() -> u64 {
    match std::fs::read_to_string("/proc/self/status") {
        Ok(text) => text
            .lines()
            .find(|l| l.starts_with("VmHWM:"))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|kb| kb.parse::<u64>().ok())
            .map(|kb| kb * 1024)
            .unwrap_or(0),
        Err(_) => 0,
    }
}

fn p99(mut xs: Vec<u64>) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    xs.sort_unstable();
    xs[(xs.len() * 99 / 100).min(xs.len() - 1)]
}

/// One client session of the multiplexed driver.
struct Session {
    stream: UnixStream,
    rbuf: FrameBuf,
    /// Unsent tail of the current request frame.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Ping round-trips left, including any in flight.
    remaining: usize,
    sent_at: Instant,
    /// Registered interest, mirrors the poller (read, write).
    interest: (bool, bool),
    done: bool,
}

/// Result of driving `sessions` concurrent connections for `pings`
/// round-trips each against the socket at `path`.
struct DriveResult {
    completed_sessions: usize,
    replies: u64,
    elapsed_s: f64,
    p99_ns: u64,
}

/// Connect `sessions` sockets and pump `pings` strict request/reply
/// round-trips on each from one non-blocking event loop.
fn drive(path: &PathBuf, sessions: usize, pings: usize) -> std::io::Result<DriveResult> {
    let ping_frame = {
        let mut bytes = Vec::new();
        write_msg(&mut bytes, &obj(vec![("method", s("ping"))])).expect("encode ping");
        bytes
    };

    let mut poller = Poller::new()?;
    let mut conns: Vec<Session> = Vec::with_capacity(sessions);
    let t0 = Instant::now();
    for k in 0..sessions {
        // The accept side keeps up easily, but a connect burst can
        // momentarily fill the listen backlog: retry briefly.
        let stream = loop {
            match UnixStream::connect(path) {
                Ok(st) => break st,
                Err(e) => match e.kind() {
                    ErrorKind::WouldBlock | ErrorKind::ConnectionRefused => {
                        std::thread::yield_now()
                    }
                    _ => return Err(e),
                },
            }
        };
        stream.set_nonblocking(true)?;
        poller.register(stream.as_raw_fd(), k as u64, false, false)?;
        conns.push(Session {
            stream,
            rbuf: FrameBuf::new(),
            wbuf: Vec::new(),
            wpos: 0,
            remaining: pings,
            sent_at: t0,
            interest: (false, false),
            done: false,
        });
    }

    let mut latencies: Vec<u64> = Vec::with_capacity(sessions.min(65_536) * pings.min(8));
    let mut replies = 0u64;
    let mut live = sessions;
    let start = Instant::now();
    // Seed every session's first request.
    for (k, c) in conns.iter_mut().enumerate() {
        arm_request(c, &ping_frame);
        pump(&mut poller, c, k, &ping_frame, &mut latencies, &mut replies);
        if c.done {
            live -= 1;
            let _ = poller.deregister(c.stream.as_raw_fd());
        }
    }
    let mut events = Events::with_capacity(1024);
    while live > 0 {
        poller.wait(&mut events, 10_000)?;
        for e in 0..events.len() {
            let k = events.get(e).token as usize;
            if conns[k].done {
                continue;
            }
            pump(&mut poller, &mut conns[k], k, &ping_frame, &mut latencies, &mut replies);
            if conns[k].done {
                live -= 1;
                // Stop watching immediately: a dead peer's EPOLLHUP
                // would otherwise re-fire on every subsequent wait.
                let _ = poller.deregister(conns[k].stream.as_raw_fd());
            }
        }
    }
    let elapsed_s = start.elapsed().as_secs_f64();
    let completed = conns.iter().filter(|c| c.remaining == 0).count();
    Ok(DriveResult { completed_sessions: completed, replies, elapsed_s, p99_ns: p99(latencies) })
}

fn arm_request(c: &mut Session, ping_frame: &[u8]) {
    c.wbuf.clear();
    c.wbuf.extend_from_slice(ping_frame);
    c.wpos = 0;
    c.sent_at = Instant::now();
}

/// Drive one session as far as it can go right now: flush the pending
/// request, then consume replies, arming follow-up requests until the
/// socket would block or the schedule is done.  Adjusts poller
/// interest to exactly what the session still waits for.
fn pump(
    poller: &mut Poller,
    c: &mut Session,
    token: usize,
    ping_frame: &[u8],
    latencies: &mut Vec<u64>,
    replies: &mut u64,
) {
    loop {
        // Flush the current request.
        while c.wpos < c.wbuf.len() {
            match c.stream.write(&c.wbuf[c.wpos..]) {
                Ok(0) => {
                    c.done = true;
                    return;
                }
                Ok(n) => c.wpos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    set_interest(poller, c, token, (false, true));
                    return;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    c.done = true;
                    return;
                }
            }
        }
        // Await the reply.
        let mut got_frame = false;
        loop {
            match c.rbuf.next_frame() {
                Ok(Some(_body)) => {
                    got_frame = true;
                    break;
                }
                Ok(None) => {}
                Err(_) => {
                    c.done = true;
                    return;
                }
            }
            let space = c.rbuf.space();
            match c.stream.read(space) {
                Ok(0) => {
                    c.done = true;
                    return;
                }
                Ok(n) => c.rbuf.commit(n),
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    set_interest(poller, c, token, (true, false));
                    return;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    c.done = true;
                    return;
                }
            }
        }
        if got_frame {
            latencies.push(c.sent_at.elapsed().as_nanos() as u64);
            *replies += 1;
            c.remaining -= 1;
            if c.remaining == 0 {
                c.done = true;
                set_interest(poller, c, token, (false, false));
                return;
            }
            arm_request(c, ping_frame);
        }
    }
}

fn set_interest(poller: &mut Poller, c: &mut Session, token: usize, want: (bool, bool)) {
    if c.interest != want {
        let _ = poller.reregister(c.stream.as_raw_fd(), token as u64, want.0, want.1);
        c.interest = want;
    }
}

/// The pre-reactor architecture, reproduced faithfully for the
/// baseline: a blocking accept loop spawning one thread per
/// connection, each bridging read_msg → dispatcher channel →
/// per-request reply channel → write_msg.  The dispatcher answers
/// pings exactly like the daemon's `handle_cheap` (constant work), so
/// the comparison isolates the transport architecture.
struct ThreadPerConnServer {
    path: PathBuf,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    dispatch: Option<std::thread::JoinHandle<()>>,
    tx: mpsc::Sender<Option<mpsc::Sender<Value>>>,
}

impl ThreadPerConnServer {
    fn start(path: PathBuf) -> std::io::Result<ThreadPerConnServer> {
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Option<mpsc::Sender<Value>>>();
        let dispatch = std::thread::spawn(move || {
            let mut users = 0i64;
            while let Ok(Some(reply)) = rx.recv() {
                users += 1;
                let _ = reply.send(obj(vec![("status", s("ok")), ("user", i(users))]));
            }
        });
        let accept = {
            let stop = stop.clone();
            let tx = tx.clone();
            std::thread::spawn(move || {
                while let Ok((mut stream, _)) = listener.accept() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let tx = tx.clone();
                    std::thread::spawn(move || {
                        while let Ok(_req) = read_msg(&mut stream) {
                            let (rtx, rrx) = mpsc::channel();
                            if tx.send(Some(rtx)).is_err() {
                                break;
                            }
                            let Ok(resp) = rrx.recv() else { break };
                            if write_msg(&mut stream, &resp).is_err() {
                                break;
                            }
                        }
                    });
                }
            })
        };
        Ok(ThreadPerConnServer { path, stop, accept: Some(accept), dispatch: Some(dispatch), tx })
    }
}

impl Drop for ThreadPerConnServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = UnixStream::connect(&self.path);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let _ = self.tx.send(None);
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Conservative estimate of how many more threads this process can
/// spawn — the thread-per-connection baseline needs one per session.
/// `usize::MAX` when the kernel does not say.
fn thread_budget() -> usize {
    std::fs::read_to_string("/proc/sys/kernel/threads-max")
        .ok()
        .and_then(|t| t.trim().parse::<usize>().ok())
        // threads-max is system-wide and shared with everything else
        // running: claim at most half, minus slack for the daemon and
        // driver threads.
        .map(|max| (max / 2).saturating_sub(64))
        .unwrap_or(usize::MAX)
}

fn main() {
    let smoke = fos::testutil::bench_smoke();
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let limit = raise_nofile_limit();
    // Two fds per session plus slack for the daemon/driver plumbing.
    let fd_budget_sessions = ((limit.saturating_sub(256)) / 2) as usize;

    let levels: &[usize] =
        if smoke { &[1_000, 10_000, 20_000] } else { &[1_000, 10_000, 50_000, 100_000] };
    let pings = if smoke { 2 } else { 5 };
    // The N-shard plane: as many shards as the machine has cores,
    // bounded to keep the sweep's wall-clock sane (on a 1-core runner
    // 2 shards still exercises every cross-shard path).
    let nshard = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 4);
    println!(
        "fd limit {limit} (budget: {fd_budget_sessions} sessions), {pings} pings/session, \
         shard sweep 1 vs {nshard}"
    );

    let sock_dir = std::env::temp_dir();

    // --- reactor sweep: 1 shard, then N shards --------------------
    let mut configs: Vec<Value> = Vec::new();
    // Per shard-config: (shards, max sessions sustained, top-level rate).
    let mut outcomes: Vec<(usize, usize, f64)> = Vec::new();
    for &shards in &[1usize, nshard] {
        let path =
            sock_dir.join(format!("fos_fig25_reactor{shards}_{}.sock", std::process::id()));
        let mut entries: Vec<Value> = Vec::new();
        let mut sustained = 0usize;
        let mut top_rate = 0.0f64;
        for &want in levels {
            let sessions = want.min(fd_budget_sessions);
            if sessions < want {
                println!("  level {want}: CLAMPED to {sessions} sessions by the fd limit");
            }
            let cfg = DaemonConfig::new(&[ShellBoard::Ultra96], catalog.clone())
                .max_connections(sessions + 64)
                .reactor_shards(shards);
            let mut daemon = Daemon::start_configured(&path, cfg).expect("daemon start");
            let r = drive(&path, sessions, pings).expect("reactor drive");
            daemon.shutdown();
            let rate = r.replies as f64 / r.elapsed_s;
            if r.completed_sessions == sessions {
                sustained = sustained.max(sessions);
            }
            top_rate = rate;
            println!(
                "  reactor x{shards} {sessions:>6} sessions: {} replies in {:.3} s -> \
                 {:.0} req/s, p99 {:.1} us, {}/{} completed",
                r.replies,
                r.elapsed_s,
                rate,
                r.p99_ns as f64 / 1e3,
                r.completed_sessions,
                sessions,
            );
            entries.push(obj(vec![
                ("sessions", i(sessions as i64)),
                ("completed_sessions", i(r.completed_sessions as i64)),
                ("replies", i(r.replies as i64)),
                ("reqs_per_sec", f(rate)),
                ("p99_rtt_ns", f(r.p99_ns as f64)),
            ]));
        }
        outcomes.push((shards, sustained, top_rate));
        configs.push(obj(vec![
            ("shards", i(shards as i64)),
            ("max_sessions_sustained", i(sustained as i64)),
            ("reqs_per_sec_top", f(top_rate)),
            ("levels", arr(entries)),
        ]));
    }
    let reactor_peak_rss = peak_rss_bytes();
    println!("  reactor peak RSS: {:.1} MiB", reactor_peak_rss as f64 / (1024.0 * 1024.0));

    let (_, sustained_1shard, reactor_top_rate) = outcomes[0];
    let (_, sustained_nshard, _) = outcomes[1];
    let sustained = sustained_1shard.max(sustained_nshard);
    // Sessions-based ratio (not throughput): robust on starved CI
    // runners, and exactly the acceptance claim — N shards must
    // sustain at least what one shard sustains.
    let shard_ratio = if sustained_1shard > 0 {
        sustained_nshard as f64 / sustained_1shard as f64
    } else {
        0.0
    };

    // --- thread-per-connection baseline at 1k ---------------------
    // The baseline spends one thread and two fds per session, so it
    // could never run the 100k sweep — it is measured at 1k, and
    // skipped with a loud note (never a silent pass, and never an
    // abort of the whole bench) when even 1k is beyond the fd or
    // thread budget.
    let baseline_sessions = 1_000usize;
    let threads = thread_budget();
    let mut skip_reason: Option<String> = None;
    if baseline_sessions > fd_budget_sessions {
        skip_reason =
            Some(format!("fd budget covers {fd_budget_sessions} sessions < {baseline_sessions}"));
    } else if baseline_sessions > threads {
        skip_reason =
            Some(format!("thread budget covers {threads} sessions < {baseline_sessions}"));
    }
    let baseline = match &skip_reason {
        Some(_) => None,
        None => {
            let baseline_path =
                sock_dir.join(format!("fos_fig25_threads_{}.sock", std::process::id()));
            match ThreadPerConnServer::start(baseline_path.clone())
                .and_then(|srv| drive(&srv.path, baseline_sessions, pings))
            {
                Ok(r) => Some(r),
                Err(e) => {
                    skip_reason = Some(format!("baseline failed to run: {e}"));
                    None
                }
            }
        }
    };
    let (baseline_rate, baseline_p99_ns) = match &baseline {
        Some(r) => {
            let rate = r.replies as f64 / r.elapsed_s;
            println!(
                "  threads {baseline_sessions:>6} sessions: {} replies in {:.3} s -> \
                 {:.0} req/s, p99 {:.1} us",
                r.replies,
                r.elapsed_s,
                rate,
                r.p99_ns as f64 / 1e3,
            );
            (rate, r.p99_ns as f64)
        }
        None => {
            println!(
                "  threads: BASELINE SKIPPED ({}) — reactor_vs_thread_ratio will be 0 \
                 and fail its floor; raise the budget to arm the comparison",
                skip_reason.as_deref().unwrap_or("unknown"),
            );
            (0.0, 0.0)
        }
    };
    let ratio = if baseline_rate > 0.0 { reactor_top_rate / baseline_rate } else { 0.0 };
    println!(
        "  sessions sustained: {sustained} (1-shard {sustained_1shard}, \
         {nshard}-shard {sustained_nshard}, ratio {shard_ratio:.2}); \
         reactor@top vs threads@1k ratio: {ratio:.2}"
    );

    let mut baseline_fields = vec![("sessions", i(baseline_sessions as i64))];
    match &skip_reason {
        Some(why) => {
            baseline_fields.push(("skipped", b(true)));
            baseline_fields.push(("skip_reason", s(why.clone())));
        }
        None => {
            let r = baseline.as_ref().expect("measured unless skipped");
            baseline_fields.push(("replies", i(r.replies as i64)));
            baseline_fields.push(("reqs_per_sec", f(baseline_rate)));
            baseline_fields.push(("p99_rtt_ns", f(baseline_p99_ns)));
        }
    }
    let doc = obj(vec![
        ("bench", s("fig25_connection_scaling")),
        ("smoke", b(smoke)),
        ("pings_per_session", i(pings as i64)),
        ("fd_limit", i(limit as i64)),
        ("reactor_shards", i(nshard as i64)),
        ("sessions_sustained", f(sustained as f64)),
        ("nshard_vs_1shard_ratio", f(shard_ratio)),
        ("reactor_vs_thread_ratio", f(ratio)),
        ("peak_rss_bytes", f(reactor_peak_rss as f64)),
        ("configs", arr(configs)),
        ("thread_per_conn_baseline", obj(baseline_fields)),
    ]);
    match fos::testutil::write_bench_json("fig25_connection_scaling", &doc) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
