//! Fig 25: connection scaling of the daemon's reactor network plane.
//!
//! Sweeps 1k → 10k concurrent sessions against a live daemon, each
//! session issuing ping RPCs over its own Unix-domain connection, and
//! measures requests/second, p99 round-trip latency and peak resident
//! memory.  A faithful in-bench reproduction of the pre-reactor
//! architecture — one blocking thread per connection bridging to a
//! dispatcher channel — is measured at 1k sessions as the baseline.
//!
//! The client driver is itself a single multiplexed non-blocking event
//! loop built on the public `fos::daemon::transport` poller/framing
//! types, so a 10k-session sweep costs 10k sockets, not 10k threads,
//! and both the reactor daemon and the thread-per-connection baseline
//! are driven identically.
//!
//! Emits `BENCH_fig25_connection_scaling.json` with two floor-gated
//! leaves (`scripts/check_bench_regression.py`):
//!
//! * `sessions_sustained` — every session of the largest sweep level
//!   connected and completed its full ping schedule (floor: 10 000);
//! * `reactor_vs_thread_ratio` — reactor requests/sec at the largest
//!   level divided by the thread-per-connection baseline's at 1k
//!   (floor: the reactor must not be slower than the architecture it
//!   replaced, despite serving 10x the sessions).

use fos::accel::Catalog;
use fos::daemon::transport::{Events, FrameBuf, Poller};
use fos::daemon::{read_msg, write_msg, Daemon};
use fos::json::{arr, b, f, i, obj, s, Value};
use fos::sched::{AdmissionConfig, PlacementKind, Policy};
use fos::shell::ShellBoard;
use std::io::{ErrorKind, Read, Write};
use std::os::fd::AsRawFd;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Raise RLIMIT_NOFILE to its hard cap (two fds per session live in
/// this one process: the client socket and the daemon's accepted end).
/// Returns the resulting soft limit.
#[cfg(target_os = "linux")]
fn raise_nofile_limit() -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return 1024;
        }
        if r.cur < r.max {
            let want = RLimit { cur: r.max, max: r.max };
            let _ = setrlimit(RLIMIT_NOFILE, &want);
            if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
                return 1024;
            }
        }
        r.cur
    }
}

#[cfg(not(target_os = "linux"))]
fn raise_nofile_limit() -> u64 {
    1024
}

/// Peak resident set (VmHWM) of this process in bytes; 0 when /proc is
/// unavailable.  Both the daemon and the bench driver live in this
/// process, so this is the whole experiment's memory high-water mark.
fn peak_rss_bytes() -> u64 {
    match std::fs::read_to_string("/proc/self/status") {
        Ok(text) => text
            .lines()
            .find(|l| l.starts_with("VmHWM:"))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|kb| kb.parse::<u64>().ok())
            .map(|kb| kb * 1024)
            .unwrap_or(0),
        Err(_) => 0,
    }
}

fn p99(mut xs: Vec<u64>) -> u64 {
    if xs.is_empty() {
        return 0;
    }
    xs.sort_unstable();
    xs[(xs.len() * 99 / 100).min(xs.len() - 1)]
}

/// One client session of the multiplexed driver.
struct Session {
    stream: UnixStream,
    rbuf: FrameBuf,
    /// Unsent tail of the current request frame.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Ping round-trips left, including any in flight.
    remaining: usize,
    sent_at: Instant,
    /// Registered interest, mirrors the poller (read, write).
    interest: (bool, bool),
    done: bool,
}

/// Result of driving `sessions` concurrent connections for `pings`
/// round-trips each against the socket at `path`.
struct DriveResult {
    completed_sessions: usize,
    replies: u64,
    elapsed_s: f64,
    p99_ns: u64,
}

/// Connect `sessions` sockets and pump `pings` strict request/reply
/// round-trips on each from one non-blocking event loop.
fn drive(path: &PathBuf, sessions: usize, pings: usize) -> std::io::Result<DriveResult> {
    let ping_frame = {
        let mut bytes = Vec::new();
        write_msg(&mut bytes, &obj(vec![("method", s("ping"))])).expect("encode ping");
        bytes
    };

    let mut poller = Poller::new()?;
    let mut conns: Vec<Session> = Vec::with_capacity(sessions);
    let t0 = Instant::now();
    for k in 0..sessions {
        // The accept side keeps up easily, but a connect burst can
        // momentarily fill the listen backlog: retry briefly.
        let stream = loop {
            match UnixStream::connect(path) {
                Ok(st) => break st,
                Err(e) => match e.kind() {
                    ErrorKind::WouldBlock | ErrorKind::ConnectionRefused => {
                        std::thread::yield_now()
                    }
                    _ => return Err(e),
                },
            }
        };
        stream.set_nonblocking(true)?;
        poller.register(stream.as_raw_fd(), k as u64, false, false)?;
        conns.push(Session {
            stream,
            rbuf: FrameBuf::new(),
            wbuf: Vec::new(),
            wpos: 0,
            remaining: pings,
            sent_at: t0,
            interest: (false, false),
            done: false,
        });
    }

    let mut latencies: Vec<u64> = Vec::with_capacity(sessions.min(65_536) * pings.min(8));
    let mut replies = 0u64;
    let mut live = sessions;
    let start = Instant::now();
    // Seed every session's first request.
    for (k, c) in conns.iter_mut().enumerate() {
        arm_request(c, &ping_frame);
        pump(&mut poller, c, k, &ping_frame, &mut latencies, &mut replies);
        if c.done {
            live -= 1;
            let _ = poller.deregister(c.stream.as_raw_fd());
        }
    }
    let mut events = Events::with_capacity(1024);
    while live > 0 {
        poller.wait(&mut events, 10_000)?;
        for e in 0..events.len() {
            let k = events.get(e).token as usize;
            if conns[k].done {
                continue;
            }
            pump(&mut poller, &mut conns[k], k, &ping_frame, &mut latencies, &mut replies);
            if conns[k].done {
                live -= 1;
                // Stop watching immediately: a dead peer's EPOLLHUP
                // would otherwise re-fire on every subsequent wait.
                let _ = poller.deregister(conns[k].stream.as_raw_fd());
            }
        }
    }
    let elapsed_s = start.elapsed().as_secs_f64();
    let completed = conns.iter().filter(|c| c.remaining == 0).count();
    Ok(DriveResult { completed_sessions: completed, replies, elapsed_s, p99_ns: p99(latencies) })
}

fn arm_request(c: &mut Session, ping_frame: &[u8]) {
    c.wbuf.clear();
    c.wbuf.extend_from_slice(ping_frame);
    c.wpos = 0;
    c.sent_at = Instant::now();
}

/// Drive one session as far as it can go right now: flush the pending
/// request, then consume replies, arming follow-up requests until the
/// socket would block or the schedule is done.  Adjusts poller
/// interest to exactly what the session still waits for.
fn pump(
    poller: &mut Poller,
    c: &mut Session,
    token: usize,
    ping_frame: &[u8],
    latencies: &mut Vec<u64>,
    replies: &mut u64,
) {
    loop {
        // Flush the current request.
        while c.wpos < c.wbuf.len() {
            match c.stream.write(&c.wbuf[c.wpos..]) {
                Ok(0) => {
                    c.done = true;
                    return;
                }
                Ok(n) => c.wpos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    set_interest(poller, c, token, (false, true));
                    return;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    c.done = true;
                    return;
                }
            }
        }
        // Await the reply.
        let mut got_frame = false;
        loop {
            match c.rbuf.next_frame() {
                Ok(Some(_body)) => {
                    got_frame = true;
                    break;
                }
                Ok(None) => {}
                Err(_) => {
                    c.done = true;
                    return;
                }
            }
            let space = c.rbuf.space();
            match c.stream.read(space) {
                Ok(0) => {
                    c.done = true;
                    return;
                }
                Ok(n) => c.rbuf.commit(n),
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    set_interest(poller, c, token, (true, false));
                    return;
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => {
                    c.done = true;
                    return;
                }
            }
        }
        if got_frame {
            latencies.push(c.sent_at.elapsed().as_nanos() as u64);
            *replies += 1;
            c.remaining -= 1;
            if c.remaining == 0 {
                c.done = true;
                set_interest(poller, c, token, (false, false));
                return;
            }
            arm_request(c, ping_frame);
        }
    }
}

fn set_interest(poller: &mut Poller, c: &mut Session, token: usize, want: (bool, bool)) {
    if c.interest != want {
        let _ = poller.reregister(c.stream.as_raw_fd(), token as u64, want.0, want.1);
        c.interest = want;
    }
}

/// The pre-reactor architecture, reproduced faithfully for the
/// baseline: a blocking accept loop spawning one thread per
/// connection, each bridging read_msg → dispatcher channel →
/// per-request reply channel → write_msg.  The dispatcher answers
/// pings exactly like the daemon's `handle_cheap` (constant work), so
/// the comparison isolates the transport architecture.
struct ThreadPerConnServer {
    path: PathBuf,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    dispatch: Option<std::thread::JoinHandle<()>>,
    tx: mpsc::Sender<Option<mpsc::Sender<Value>>>,
}

impl ThreadPerConnServer {
    fn start(path: PathBuf) -> std::io::Result<ThreadPerConnServer> {
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)?;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Option<mpsc::Sender<Value>>>();
        let dispatch = std::thread::spawn(move || {
            let mut users = 0i64;
            while let Ok(Some(reply)) = rx.recv() {
                users += 1;
                let _ = reply.send(obj(vec![("status", s("ok")), ("user", i(users))]));
            }
        });
        let accept = {
            let stop = stop.clone();
            let tx = tx.clone();
            std::thread::spawn(move || {
                while let Ok((mut stream, _)) = listener.accept() {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    let tx = tx.clone();
                    std::thread::spawn(move || {
                        while let Ok(_req) = read_msg(&mut stream) {
                            let (rtx, rrx) = mpsc::channel();
                            if tx.send(Some(rtx)).is_err() {
                                break;
                            }
                            let Ok(resp) = rrx.recv() else { break };
                            if write_msg(&mut stream, &resp).is_err() {
                                break;
                            }
                        }
                    });
                }
            })
        };
        Ok(ThreadPerConnServer { path, stop, accept: Some(accept), dispatch: Some(dispatch), tx })
    }
}

impl Drop for ThreadPerConnServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = UnixStream::connect(&self.path);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let _ = self.tx.send(None);
        if let Some(h) = self.dispatch.take() {
            let _ = h.join();
        }
        let _ = std::fs::remove_file(&self.path);
    }
}

fn main() {
    let smoke = fos::testutil::bench_smoke();
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let limit = raise_nofile_limit();
    // Two fds per session plus slack for the daemon/driver plumbing.
    let fd_budget_sessions = ((limit.saturating_sub(256)) / 2) as usize;

    let levels: &[usize] = if smoke { &[1_000, 10_000] } else { &[1_000, 4_000, 10_000] };
    let pings = if smoke { 2 } else { 5 };
    println!("fd limit {limit} (budget: {fd_budget_sessions} sessions), {pings} pings/session");

    let sock_dir = std::env::temp_dir();
    let reactor_path = sock_dir.join(format!("fos_fig25_reactor_{}.sock", std::process::id()));

    // --- reactor sweep --------------------------------------------
    let mut entries: Vec<Value> = Vec::new();
    let mut sustained = 0usize;
    let mut reactor_top_rate = 0.0f64;
    for &want in levels {
        let sessions = want.min(fd_budget_sessions);
        if sessions < want {
            println!("  level {want}: CLAMPED to {sessions} sessions by the fd limit");
        }
        let mut daemon = Daemon::start_cluster_configured(
            &reactor_path,
            &[ShellBoard::Ultra96],
            catalog.clone(),
            Policy::Elastic,
            PlacementKind::Locality,
            AdmissionConfig::default(),
            sessions + 64,
        )
        .expect("daemon start");
        let r = drive(&reactor_path, sessions, pings).expect("reactor drive");
        daemon.shutdown();
        let rate = r.replies as f64 / r.elapsed_s;
        if r.completed_sessions == sessions {
            sustained = sustained.max(sessions);
        }
        reactor_top_rate = rate;
        println!(
            "  reactor {sessions:>6} sessions: {} replies in {:.3} s -> {:.0} req/s, \
             p99 {:.1} us, {}/{} completed",
            r.replies,
            r.elapsed_s,
            rate,
            r.p99_ns as f64 / 1e3,
            r.completed_sessions,
            sessions,
        );
        entries.push(obj(vec![
            ("sessions", i(sessions as i64)),
            ("completed_sessions", i(r.completed_sessions as i64)),
            ("replies", i(r.replies as i64)),
            ("reqs_per_sec", f(rate)),
            ("p99_rtt_ns", f(r.p99_ns as f64)),
        ]));
    }
    let reactor_peak_rss = peak_rss_bytes();
    println!("  reactor peak RSS: {:.1} MiB", reactor_peak_rss as f64 / (1024.0 * 1024.0));

    // --- thread-per-connection baseline at 1k ---------------------
    let baseline_sessions = 1_000usize.min(fd_budget_sessions);
    let baseline_path = sock_dir.join(format!("fos_fig25_threads_{}.sock", std::process::id()));
    let baseline = {
        let srv = ThreadPerConnServer::start(baseline_path.clone()).expect("baseline start");
        drive(&srv.path, baseline_sessions, pings).expect("baseline drive")
    };
    let baseline_rate = baseline.replies as f64 / baseline.elapsed_s;
    println!(
        "  threads {baseline_sessions:>6} sessions: {} replies in {:.3} s -> {:.0} req/s, \
         p99 {:.1} us",
        baseline.replies,
        baseline.elapsed_s,
        baseline_rate,
        baseline.p99_ns as f64 / 1e3,
    );
    let ratio = if baseline_rate > 0.0 { reactor_top_rate / baseline_rate } else { 0.0 };
    println!(
        "  sessions sustained: {sustained}; reactor@top vs threads@1k ratio: {ratio:.2}"
    );

    let doc = obj(vec![
        ("bench", s("fig25_connection_scaling")),
        ("smoke", b(smoke)),
        ("pings_per_session", i(pings as i64)),
        ("fd_limit", i(limit as i64)),
        ("sessions_sustained", f(sustained as f64)),
        ("reactor_vs_thread_ratio", f(ratio)),
        ("peak_rss_bytes", f(reactor_peak_rss as f64)),
        ("reactor", arr(entries)),
        (
            "thread_per_conn_baseline",
            obj(vec![
                ("sessions", i(baseline_sessions as i64)),
                ("replies", i(baseline.replies as i64)),
                ("reqs_per_sec", f(baseline_rate)),
                ("p99_rtt_ns", f(baseline.p99_ns as f64)),
            ]),
        ),
    ]);
    match fos::testutil::write_bench_json("fig25_connection_scaling", &doc) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
