//! Fig 15: resource allocation over time for tasks A–D under (a) fixed
//! module scheduling vs (b) resource-elastic scheduling, on the
//! 4-region ZCU102 shell. Prints the allocation timeline as ASCII and
//! the makespans.

use fos::accel::Catalog;
use fos::metrics::{sched_summary, Table};
use fos::sched::{simulate, JobSpec, Policy, SimConfig, SimResult, Workload};
use fos::shell::ShellBoard;

fn workload() -> Workload {
    // Four tasks with staggered arrivals (the paper's circled events:
    // new tasks arriving while others hold the fabric).
    let mut w = Workload::new();
    // Paper-scale tasks: tens of ms of accelerator work each, so
    // replication/replacement amortise their reconfigurations.
    let tasks = [
        (0usize, "dct", 0u64, 480usize, 8usize),          // A
        (1, "mandelbrot", 6_000_000, 24, 6),              // B
        (2, "fir", 12_000_000, 480, 6),                   // C
        (3, "black_scholes", 60_000_000, 160, 8),         // D
    ];
    for (u, accel, arrival, tiles, reqs) in tasks {
        for j in JobSpec::frame(u, accel, arrival, tiles, reqs) {
            w.push(j);
        }
    }
    w
}

fn timeline(r: &SimResult, regions: usize, label: &str) {
    println!("\n{label} — allocation timeline (each column = 2 ms):");
    let end = r.makespan;
    let cols = 60usize;
    let step = (end / cols as u64).max(1);
    for reg in 0..regions {
        let mut line = String::new();
        for c in 0..cols {
            let t = c as u64 * step;
            let ev = r
                .trace
                .iter()
                .find(|e| e.region <= reg && reg < e.region + e.span && e.start <= t && t < e.end);
            line.push(match ev {
                Some(e) => (b'A' + e.user as u8) as char,
                None => '.',
            });
        }
        println!("  pr{reg}: {line}");
    }
}

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let w = workload();
    let el = simulate(&catalog, &w, &SimConfig::new(ShellBoard::Zcu102, Policy::Elastic));
    let fx = simulate(&catalog, &w, &SimConfig::new(ShellBoard::Zcu102, Policy::Fixed));

    timeline(&fx, 4, "(a) standard fixed-module scheduling");
    timeline(&el, 4, "(b) FOS resource-elastic scheduling");

    let mut t = Table::new(
        "Fig 15 — makespan and per-task completion (ms)",
        &["metric", "fixed", "elastic", "gain"],
    );
    t.row(&[
        "makespan".into(),
        format!("{:.2}", fx.makespan as f64 / 1e6),
        format!("{:.2}", el.makespan as f64 / 1e6),
        format!("{:.2}x", fx.makespan as f64 / el.makespan as f64),
    ]);
    for u in 0..4 {
        t.row(&[
            format!("task {} done", (b'A' + u as u8) as char),
            format!("{:.2}", fx.user_completion[u] as f64 / 1e6),
            format!("{:.2}", el.user_completion[u] as f64 / 1e6),
            format!(
                "{:.2}x",
                fx.user_completion[u] as f64 / el.user_completion[u].max(1) as f64
            ),
        ]);
    }
    t.print();
    // Both policies run through the same SchedCore; report its shared
    // counters (the daemon's DaemonStats mirrors the identical set).
    println!("{}", sched_summary("elastic", &el.counters));
    println!("{}", sched_summary("fixed  ", &fx.counters));
    println!(
        "elastic decision log: {} placements, first = {:?}",
        el.decisions.len(),
        el.decisions.first().map(|d| (&d.accel, &d.variant, d.anchor, d.span))
    );
    assert!(el.makespan < fx.makespan, "elastic must beat fixed");
    assert!(el.counters.replications >= 1, "elastic run should replicate for task A's backlog");
}
