//! Table 4: execution overhead of the software layers — REAL
//! measurements of this stack (daemon init, JSON parsing, RPC round
//! trip, scheduling decision), not models.

use fos::accel::Catalog;
use fos::daemon::{Daemon, FpgaRpc, Job};
use fos::metrics::{LatencyStats, Table};
use fos::registry::Registry;
use fos::shell::{Shell, ShellBoard};
use std::sync::atomic::Ordering;
use std::time::Instant;

fn main() {
    let socket = std::env::temp_dir().join(format!("fos_t4_{}.sock", std::process::id()));
    let catalog = Catalog::load_default().expect("run `make artifacts`");

    // --- daemon + RPC init (paper: "Initialize gRPC (once)" 12.20 ms) --
    let t0 = Instant::now();
    let daemon = Daemon::start(&socket, ShellBoard::Ultra96, catalog.clone()).unwrap();
    let mut rpc = FpgaRpc::connect(&socket).unwrap();
    let init_ms = t0.elapsed().as_secs_f64() * 1e3;

    // --- JSON parsing (paper 2.27 ms): full registry save + reload -----
    let shell = Shell::build(ShellBoard::Ultra96);
    let reg = Registry::populate(&shell, &catalog).unwrap();
    let path = std::env::temp_dir().join(format!("fos_t4_{}.json", std::process::id()));
    reg.save(&path).unwrap();
    let mut parse_stats = LatencyStats::new();
    for _ in 0..fos::testutil::bench_scale(50, 10) {
        let t = Instant::now();
        let _r = Registry::load(&path).unwrap();
        parse_stats.record(t.elapsed());
    }
    std::fs::remove_file(&path).ok();

    // --- RPC call (paper 0.71 ms): ping round trips --------------------
    let mut ping_stats = LatencyStats::new();
    for _ in 0..fos::testutil::bench_scale(200, 50) {
        ping_stats.record(rpc.ping().unwrap());
    }

    // --- Scheduler (paper 0.02 ms): daemon-side decision time ----------
    // Run a batch of vadd jobs so the dispatcher records decisions.
    let a = rpc.alloc(4 * 4096).unwrap();
    let b = rpc.alloc(4 * 4096).unwrap();
    let c = rpc.alloc(4 * 4096).unwrap();
    rpc.write_f32(a, &vec![1.0; 4096]).unwrap();
    rpc.write_f32(b, &vec![2.0; 4096]).unwrap();
    let jobs: Vec<Job> = (0..fos::testutil::bench_scale(50, 10))
        .map(|_| Job::new(
            "vadd",
            vec![("a_op".into(), a), ("b_op".into(), b), ("c_out".into(), c)],
        ))
        .collect();
    // Decisions (the quantity measured here) land even when the PJRT
    // backend is the offline stub and compute errors out.
    let _ = rpc.run(&jobs);
    let st = daemon.stats();
    let sched_ms = st.sched_ns.load(Ordering::Relaxed) as f64
        / st.sched_decisions.load(Ordering::Relaxed).max(1) as f64
        / 1e6;

    let mut t = Table::new(
        "Table 4 — software layer latencies, measured (paper), ms",
        &["software layer", "latency"],
    );
    t.row(&["Initialize RPC + daemon (once)".into(), format!("{init_ms:.2} (12.20)")]);
    t.row(&[
        "JSON parsing (once)".into(),
        format!("{:.2} (2.27)", parse_stats.mean_us() / 1e3),
    ]);
    t.row(&[
        "RPC call to daemon".into(),
        format!("{:.3} (0.71)", ping_stats.mean_us() / 1e3),
    ]);
    t.row(&["Scheduler".into(), format!("{:.4} (0.02)", sched_ms)]);
    t.print();
    println!("RPC p50 {:.1} us, p99 {:.1} us over {} pings",
        ping_stats.percentile_us(50.0), ping_stats.percentile_us(99.0), ping_stats.count());
    println!("note: UDS JSON-RPC here vs gRPC/protobuf on a Zynq A53 in the paper —");
    println!("      absolute numbers differ; the layer ordering must match.");
}
