//! Fig 22: Mandelbrot (C) and Sobel (OpenCL) executing concurrently on
//! the Ultra96 with varying request counts — execution latency relative
//! to the 1-Mandel x 1-Sobel scenario. The paper's optimum is
//! 3-Mandel x 1-Sobel; greedy (3x3) stays near-optimal.

use fos::accel::Catalog;
use fos::metrics::Table;
use fos::sched::{
    mean_turnaround_ns, simulate, JobSpec, Policy, SchedCounters, SimConfig, Workload,
};
use fos::shell::ShellBoard;

fn scenario(catalog: &Catalog, m_reqs: usize, s_reqs: usize) -> (f64, SchedCounters) {
    let mut w = Workload::new();
    for j in JobSpec::frame_pinned(0, "mandelbrot", "mandelbrot_v1", 0, 12, m_reqs) {
        w.push(j);
    }
    for j in JobSpec::frame_pinned(1, "sobel", "sobel_v1", 0, 12, s_reqs) {
        w.push(j);
    }
    let r = simulate(
        catalog,
        &w,
        &SimConfig::new(ShellBoard::Ultra96, Policy::Elastic),
    );
    (r.makespan as f64 / 1e6, r.counters)
}

fn main() {
    let catalog = Catalog::load_default().expect("run `make artifacts`");
    let (base, _) = scenario(&catalog, 1, 1);
    let mut t = Table::new(
        "Fig 22 — Mandel x Sobel concurrent on Ultra96, latency relative to 1x1",
        &["scenario", "makespan (ms)", "relative", "reconfig/reuse/skip"],
    );
    let mut best = (String::new(), f64::INFINITY);
    for m in 1..=3usize {
        for s in 1..=3usize {
            let (ms, c) = scenario(&catalog, m, s);
            let name = format!("{m}-Mandel x {s}-Sobel");
            if ms < best.1 {
                best = (name.clone(), ms);
            }
            t.row(&[
                name,
                format!("{ms:.2}"),
                format!("{:.2}", ms / base),
                format!("{}/{}/{}", c.reconfigs, c.reuses, c.skips),
            ]);
        }
    }
    t.print();
    let (greedy, _) = scenario(&catalog, 3, 3);
    println!(
        "best: {} at {:.2} ms ({:.0}% better than 1x1; paper: 46% at 3-Mandel x 1-Sobel)",
        best.0,
        best.1,
        100.0 * (1.0 - best.1 / base)
    );
    println!(
        "greedy 3x3: {:.2} ms — within {:.0}% of best (paper: greedy stays near-optimal)",
        greedy,
        100.0 * (greedy / best.1 - 1.0)
    );

    // --- time-domain elasticity: preemption vs run-to-completion ------
    // A Mandel tenant streaming three long requests next to a Sobel
    // tenant with many short ones — the mix where cooperative
    // run-to-completion starves the shorts. Mean turnaround under the
    // preemptive policies must beat the cooperative baseline.
    // `FOS_SCENARIO=<spec>` swaps the built-in mix for a scenario-engine
    // trace — the same record/replay knob the tests and the daemon use.
    let scenario_replay = fos::testutil::scenario_override();
    let w = if let Some(sc) = &scenario_replay {
        println!("FOS_SCENARIO replay: {}", sc.to_spec());
        sc.to_workload()
    } else {
        let stream_tiles = fos::testutil::bench_scale(120, 60);
        let mut w = Workload::new();
        for _ in 0..3 {
            w.push(JobSpec::stream(0, "mandelbrot", Some("mandelbrot_v1"), 0, stream_tiles));
        }
        for j in JobSpec::frame_pinned(1, "sobel", "sobel_v1", 0, 20, 10) {
            w.push(j);
        }
        w
    };
    let mut t2 = Table::new(
        "Preemptive time-multiplexing — 3 Mandel streams x 10 short Sobel jobs (Ultra96)",
        &["policy", "mean turnaround (ms)", "makespan (ms)", "preempt/resume"],
    );
    let mut means = Vec::new();
    let mut per_policy = Vec::new();
    for policy in [Policy::Elastic, Policy::Quantum, Policy::ElasticPreempt] {
        let r = simulate(&catalog, &w, &SimConfig::new(ShellBoard::Ultra96, policy));
        let mean_ns = mean_turnaround_ns(&w, &r);
        let mean_ms = mean_ns / 1e6;
        means.push((policy, mean_ms));
        per_policy.push((policy.name(), mean_ns, r.counters.clone()));
        t2.row(&[
            policy.name().into(),
            format!("{mean_ms:.2}"),
            format!("{:.2}", r.makespan as f64 / 1e6),
            format!("{}/{}", r.counters.preemptions, r.counters.resumes),
        ]);
    }
    t2.print();
    let rtc = means[0].1;
    for &(policy, mean_ms) in &means[1..] {
        println!(
            "{}: {:.1}% of the run-to-completion mean turnaround",
            policy.name(),
            100.0 * mean_ms / rtc
        );
    }

    // Machine-readable result for the CI bench-regression gate: mean
    // turnaround (virtual ns — deterministic, so a >20% drift is a real
    // scheduling regression, not machine noise), reconfiguration and
    // preemption counts per policy.
    use fos::json::{b, f, obj, s};
    let policies = obj(per_policy
        .iter()
        .map(|(name, mean_ns, c)| {
            (
                *name,
                obj(vec![
                    ("mean_turnaround_ns", f(*mean_ns)),
                    ("reconfigs", f(c.reconfigs as f64)),
                    ("preemptions", f(c.preemptions as f64)),
                    ("resumes", f(c.resumes as f64)),
                ]),
            )
        })
        .collect());
    let doc = obj(vec![
        ("bench", s("fig22_multitenant")),
        ("smoke", b(fos::testutil::bench_smoke())),
        ("scenario_override", b(scenario_replay.is_some())),
        ("policies", policies),
    ]);
    match fos::testutil::write_bench_json("fig22_multitenant", &doc) {
        Ok(p) => println!("wrote {}", p.display()),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
